"""End-to-end example: train a ~100M-param dense LM for a few hundred
steps with checkpoint/restart, through the real training stack
(optimizer, remat, data pipeline, async checkpointing).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M config: 12 layers, d_model=512, 8 heads, d_ff=2048, vocab 32k.
On this CPU container a few hundred steps of a ~25M reduced config is
the default; pass --full-100m on real hardware.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, batch_at
from repro.ckpt import checkpoint as CK
from repro.models.model import build_model
from repro.train.optimizer import AdamW
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full_100m:
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=512, num_heads=8, num_kv_heads=8,
                          d_ff=2048, vocab_size=32000, rope_theta=1e4)
        batch, seq = 32, 1024
    else:
        cfg = ModelConfig(name="lm-25m", family="dense", num_layers=4,
                          d_model=256, num_heads=4, num_kv_heads=4,
                          d_ff=1024, vocab_size=32000, rope_theta=1e4)
        batch, seq = 8, 256

    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(model.abstract_params()))
    print(f"{cfg.name}: {n/1e6:.1f}M params")

    opt = AdamW(peak_lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=2))
    state = init_state(model, jax.random.PRNGKey(0), opt)
    shape = ShapeConfig("ex", seq, batch, "train")
    dcfg = DataConfig(seed=0)
    ckpt = CK.AsyncCheckpointer(args.ckpt_dir, keep=2)

    start = 0
    if CK.latest_step(args.ckpt_dir) is not None:
        state, start = CK.restore(state, args.ckpt_dir)
        print(f"resumed at step {start}")

    for step in range(start, args.steps):
        b = jax.tree.map(jnp.asarray, batch_at(cfg, shape, dcfg, step))
        state, m = step_fn(state, b)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(state, step + 1)
    ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
