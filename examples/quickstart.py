"""Quickstart: the Ouroboros allocator public API in 60 lines.

Covers the current knobs: ``backend`` (jnp reference vs fused Pallas
kernels), ``lowering`` (whole-arena refs vs the region-blocked
compiled lowering, DESIGN.md §8), and ``num_shards`` (the sharded
multi-arena allocator with overflow routing, DESIGN.md §9).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

# An 8 MiB heap of 8 KiB chunks, size classes 16 B .. 8 KiB.
cfg = HeapConfig(total_bytes=8 << 20, chunk_bytes=8 << 10,
                 min_page_bytes=16)

sizes = jnp.asarray([16, 100, 1000, 4000, 8000] * 20, jnp.int32)
mask = jnp.ones(sizes.shape[0], bool)
tags = jnp.arange(sizes.shape[0], dtype=jnp.int32)

print("== six variants, jnp reference backend ==")
for variant in VARIANTS:
    # backend="pallas" runs each whole transaction as ONE fused
    # kernel; lowering="blocked"|"whole" picks the kernel shape
    # ("auto": blocked on TPU, whole on CPU interpret).  All paths are
    # bit-identical, so the demo uses the fast-on-CPU default.
    ouro = Ouroboros(cfg, variant, backend="jnp", lowering="auto")
    state = ouro.init()

    # Bulk allocation: one device transaction serves every lane
    # (the TPU analogue of the paper's warp-aggregated allocation).
    state, offsets = ouro.alloc(state, sizes, mask)

    # Write a tag into every allocation, verify, then free.
    state = ouro.write_pattern(state, offsets, sizes, tags)
    ok = np.asarray(ouro.check_pattern(state, offsets, sizes, tags))
    state = ouro.free(state, offsets, sizes, mask)

    granted = int((np.asarray(offsets) >= 0).sum())
    print(f"{variant:10s} granted {granted}/{sizes.shape[0]} "
          f"data_ok={bool(ok[np.asarray(offsets) >= 0].all())}")

print("\n== sharded: 4 independent arenas, overflow routing ==")
# A smaller heap keeps the demo snappy: the sharded jnp path unrolls
# one per-shard transaction per (attempt, shard) step, so trace size
# scales with num_shards * (overflow_walk + 1).
shard_cfg = HeapConfig(total_bytes=1 << 20, chunk_bytes=1 << 12,
                       min_page_bytes=16)
ouro = Ouroboros(shard_cfg, "va_page", num_shards=4,
                 overflow_walk=1)                  # DESIGN.md §9
state = ouro.init()
Ws = ouro.layout.shard_words

# default routing: hashed home shards spread the wavefront
state, offs = ouro.alloc(state, sizes, mask)
homes = np.asarray(offs) // Ws
print(f"hashed routing: grants per shard = "
      f"{[int((homes == s).sum()) for s in range(4)]}")
state = ouro.free(state, offs, sizes, mask)

# caller routing: shard_hint pins the wavefront's home (per-lane
# arrays work too — the serving engine homes each sequence this way).
# When the home shard runs out, the overflow walk (here 1 neighbor)
# serves the remainder from shard 2 instead of failing the lanes.
state, offs = ouro.alloc(state, sizes, mask, shard_hint=1)
homes = np.asarray(offs) // Ws
print(f"shard_hint=1:   grants per shard = "
      f"{[int((homes == s).sum()) for s in range(4)]}  "
      f"(spill past shard 1 = the overflow walk)")
assert set(homes[np.asarray(offs) >= 0].tolist()) <= {1, 2}
