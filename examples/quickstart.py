"""Quickstart: the Ouroboros allocator public API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

# An 8 MiB heap of 8 KiB chunks, size classes 16 B .. 8 KiB.
cfg = HeapConfig(total_bytes=8 << 20, chunk_bytes=8 << 10,
                 min_page_bytes=16)

for variant in VARIANTS:
    ouro = Ouroboros(cfg, variant)
    state = ouro.init()

    # Bulk allocation: one device transaction serves every lane
    # (the TPU analogue of the paper's warp-aggregated allocation).
    sizes = jnp.asarray([16, 100, 1000, 4000, 8000] * 20, jnp.int32)
    mask = jnp.ones(sizes.shape[0], bool)
    state, offsets = ouro.alloc(state, sizes, mask)

    # Write a tag into every allocation, verify, then free.
    tags = jnp.arange(sizes.shape[0], dtype=jnp.int32)
    state = ouro.write_pattern(state, offsets, sizes, tags)
    ok = np.asarray(ouro.check_pattern(state, offsets, sizes, tags))
    state = ouro.free(state, offsets, sizes, mask)

    granted = int((np.asarray(offsets) >= 0).sum())
    print(f"{variant:10s} granted {granted}/{sizes.shape[0]} "
          f"data_ok={bool(ok[np.asarray(offsets) >= 0].all())}")
