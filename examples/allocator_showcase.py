"""Showcase: fragmentation behaviour across the six variants (the
paper's core comparison) + the masked group ops from DESIGN.md §2.

    PYTHONPATH=src python examples/allocator_showcase.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS, groups

cfg = HeapConfig(total_bytes=1 << 20, chunk_bytes=1 << 12,
                 min_page_bytes=16)

print("== masked group ops (the paper's wished-for SYCL feature) ==")
cls = jnp.asarray([0, 2, 0, 1, 2, 2, 0], jnp.int32)
mask = jnp.asarray([1, 1, 0, 1, 1, 1, 1], bool)
rank, counts = groups.masked_rank(cls, mask, 3)
print(f"classes {list(np.asarray(cls))}, active {list(np.asarray(mask))}")
print(f"ranks   {list(np.asarray(rank))}  (dense per class)")
print(f"counts  {list(np.asarray(counts))} (one counter update per class)")
ballot = groups.masked_ballot(mask)
print(f"ballot  {int(np.asarray(ballot)[0]):07b}  (__ballot_sync analogue)\n")

print("== fragmentation: many small allocs, then one large ==")
rng = np.random.default_rng(0)
for variant in VARIANTS:
    ouro = Ouroboros(cfg, variant)
    st = ouro.init()
    # fill with 16 B allocations (fragments the heap)
    n = 2048
    sizes = jnp.full(n, 16, jnp.int32)
    st, offs = ouro.alloc(st, sizes, jnp.ones(n, bool))
    small_ok = int((np.asarray(offs) >= 0).sum())
    # free every second one
    keep = np.asarray(offs) >= 0
    freemask = keep & (np.arange(n) % 2 == 0)
    st = ouro.free(st, offs, sizes, jnp.asarray(freemask))
    # now ask for 4 KiB blocks — page variants carved their inventory at
    # init (fixed partition); chunk variants can still claim fresh chunks
    big = jnp.full(32, 4096, jnp.int32)
    st, offs2 = ouro.alloc(st, big, jnp.ones(32, bool))
    big_ok = int((np.asarray(offs2) >= 0).sum())
    print(f"{variant:10s} small granted {small_ok:4d}/2048, "
          f"4KiB after churn {big_ok:2d}/32")

print("\n== backend parity: fused Pallas lowerings vs jnp oracle ==")
small = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                   min_page_bytes=16)
sizes = jnp.asarray(rng.choice([16, 64, 256, 1024], 16), jnp.int32)
ones = jnp.ones(16, bool)
for variant in ("page", "vl_chunk"):
    st_j, offs_j = (lambda o: o.alloc(o.init(), sizes, ones))(
        Ouroboros(small, variant, backend="jnp"))
    # both kernel shapes: whole-arena refs and the region-blocked
    # compiled lowering (DESIGN.md §8) — bit-identical by contract
    for lowering in ("whole", "blocked"):
        st_p, offs_p = (lambda o: o.alloc(o.init(), sizes, ones))(
            Ouroboros(small, variant, backend="pallas",
                      lowering=lowering))
        same = bool((np.asarray(offs_j) == np.asarray(offs_p)).all())
        print(f"{variant:10s} jnp == pallas/{lowering:7s} offsets: {same}")

print("\n== sharding: overflow walk rescues an exhausted home shard ==")
# 4 shards; every lane homed on shard 0.  With the walk disabled the
# drain stops at one shard's capacity — with it, neighbors serve the
# overflow (DESIGN.md §9).
shard_cfg = HeapConfig(total_bytes=1 << 14, chunk_bytes=1 << 10,
                       min_page_bytes=64)
burst = jnp.full(64, 64, jnp.int32)       # more than one shard holds
ones64 = jnp.ones(64, bool)
for walk, label in ((0, "overflow_walk=0"), (None, "full walk")):
    ouro = Ouroboros(shard_cfg, "page", num_shards=4,
                     overflow_walk=walk)
    st, offs = ouro.alloc(ouro.init(), burst, ones64, shard_hint=0)
    offs = np.asarray(offs)
    per_shard = [int(((offs >= 0) & (offs // ouro.layout.shard_words
                                     == s)).sum()) for s in range(4)]
    print(f"{label:15s} granted {int((offs >= 0).sum()):2d}/64, "
          f"per shard {per_shard}")

print("\n== defragmentation: one wave un-strands a churned heap ==")
# Churn leaves live pages scattered over many chunks; sticky bindings
# and the stragglers lock whole chunks away from large requests.  One
# Ouroboros.defrag wave migrates the stragglers into a dense prefix
# and returns a forwarding table for the survivors (DESIGN.md §10).
from repro.core import defrag

dcfg = HeapConfig(total_bytes=1 << 15, chunk_bytes=1 << 11,
                  min_page_bytes=64)
ouro = Ouroboros(dcfg, "vl_chunk")
st = ouro.init()
live = []
sizes16 = jnp.full(16, 64, jnp.int32)
for _ in range(30):                       # drain the heap with 64 B pages
    st, offs = ouro.alloc(st, sizes16, jnp.ones(16, bool))
    live.extend(int(o) for o in np.asarray(offs) if o >= 0)
keep = set(live[::6])                     # survivors, scattered
drop = [o for o in live if o not in keep]
for i in range(0, len(drop), 16):
    fo = np.full(16, -1, np.int32)
    fo[:len(drop[i:i + 16])] = drop[i:i + 16]
    st = ouro.free(st, jnp.asarray(fo), sizes16, jnp.asarray(fo >= 0))
fs = ouro.frag_stats(st)
print(f"after churn : free={int(fs['free_words'])} words, largest "
      f"extent={int(fs['largest_free_extent'])}, "
      f"frag_ratio={float(fs['frag_ratio']):.3f}")
st, offs = ouro.alloc(st, jnp.full(4, 2048, jnp.int32), jnp.ones(4, bool))
print(f"2 KiB allocs on the churned heap: "
      f"{int((np.asarray(offs) >= 0).sum())}/4 granted")
st, fwd = ouro.defrag(st)
fs = ouro.frag_stats(st)
print(f"after defrag: moved {int((np.asarray(fwd.src) >= 0).sum())} "
      f"pages, largest extent={int(fs['largest_free_extent'])}, "
      f"frag_ratio={float(fs['frag_ratio']):.3f}")
st, offs = ouro.alloc(st, jnp.full(4, 2048, jnp.int32), jnp.ones(4, bool))
print(f"2 KiB allocs after the wave: "
      f"{int((np.asarray(offs) >= 0).sum())}/4 granted")
