"""End-to-end example: serve a small LM with continuous batching over
the Ouroboros paged KV cache — requests of mixed lengths stream through
the allocator (alloc on growth, free on completion).

    PYTHONPATH=src python examples/serve_paged.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

cfg = get_arch("qwen2-0.5b").smoke()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServingEngine(model, params, max_batch=4, max_seq=256)
rng = np.random.default_rng(0)

# 12 requests with wildly mixed prompt/output lengths — the dynamic
# partitioning workload the paper motivates (§1).
for i in range(12):
    plen = int(rng.integers(4, 60))
    eng.submit(rng.integers(2, cfg.vocab_size, plen),
               max_new_tokens=int(rng.integers(4, 24)))

done = eng.run_until_done()
for r in sorted(done, key=lambda r: r.uid):
    print(f"req {r.uid:2d}: prompt {len(r.prompt):2d} tok "
          f"→ generated {len(r.out_tokens):2d} tok")
print(f"\nallocator: {eng.stats['allocs']} pages allocated, "
      f"{eng.stats['frees']} freed, "
      f"{eng.stats['alloc_failures']} failures over "
      f"{eng.stats['steps']} engine steps")
assert eng.stats["allocs"] == eng.stats["frees"], "page leak!"
print("no page leaks — every allocation returned to the heap")
