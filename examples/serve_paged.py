"""End-to-end example: serve a small LM with continuous batching over
the Ouroboros paged KV cache — requests of mixed lengths stream through
the allocator (alloc on growth, free on completion).

    PYTHONPATH=src python examples/serve_paged.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

cfg = get_arch("qwen2-0.5b").smoke()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServingEngine(model, params, max_batch=4, max_seq=256)
rng = np.random.default_rng(0)

# 12 requests with wildly mixed prompt/output lengths — the dynamic
# partitioning workload the paper motivates (§1).
for i in range(12):
    plen = int(rng.integers(4, 60))
    eng.submit(rng.integers(2, cfg.vocab_size, plen),
               max_new_tokens=int(rng.integers(4, 24)))

done = eng.run_until_done()
for r in sorted(done, key=lambda r: r.uid):
    print(f"req {r.uid:2d}: prompt {len(r.prompt):2d} tok "
          f"→ generated {len(r.out_tokens):2d} tok")
print(f"\nallocator: {eng.stats['allocs']} pages allocated, "
      f"{eng.stats['frees']} freed, "
      f"{eng.stats['alloc_failures']} failures over "
      f"{eng.stats['steps']} engine steps")
assert eng.stats["allocs"] == eng.stats["frees"], "page leak!"
print("no page leaks — every allocation returned to the heap")

# ---- the fused decode mega-step (DESIGN.md §11) ---------------------------
# Same engine, same requests, but the whole decode tick — page growth,
# grant scatter, paged attention, greedy sampling, sequence advance —
# runs as ONE jitted device-resident function; the host syncs a (B,)
# finished/failed flag vector per token.  Token streams match the
# host loop exactly.
import jax.numpy as jnp

mega = ServingEngine(model, params, max_batch=4, max_seq=256,
                     kv_dtype=jnp.float32, compute_dtype=jnp.float32,
                     mega_step=True)
ref = ServingEngine(model, params, max_batch=4, max_seq=256,
                    kv_dtype=jnp.float32, compute_dtype=jnp.float32)
rng = np.random.default_rng(1)
prompts = [(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 40))),
            int(rng.integers(4, 12))) for _ in range(6)]
for eng2 in (ref, mega):
    for p, mx in prompts:
        eng2.submit(p, max_new_tokens=mx)
want = {r.uid: r.out_tokens for r in ref.run_until_done()}
got = {r.uid: r.out_tokens for r in mega.run_until_done()}
assert want == got, "mega-step diverged from the host loop!"
print(f"\nmega-step: {sum(len(t) for t in got.values())} tokens, "
      f"token-for-token identical to the host loop; "
      f"launches per fused tick = {mega.launches_per_tick()} "
      f"(constant in max_batch)")

# ---- crash-safe serving (DESIGN.md §12) -----------------------------------
# Snapshot the COMPLETE serving state mid-stream — arena word image +
# control block, KV page heaps + page tables, request queue — into an
# atomic on-disk checkpoint, "crash", restore into a fresh engine, and
# finish: the streams are token-identical to never having crashed.
# (launch/serve.py wires this to SIGTERM via --snapshot-dir/--resume.)
import tempfile

snapdir = tempfile.mkdtemp(prefix="serve_snap_")
eng = ServingEngine(model, params, max_batch=4, max_seq=256,
                    kv_dtype=jnp.float32, compute_dtype=jnp.float32)
rng = np.random.default_rng(1)
for p, mx in prompts:
    eng.submit(p, max_new_tokens=mx)
early = []
for _ in range(4):                       # a few ticks...
    early.extend(eng.step())
eng.snapshot(directory=snapdir)          # ...snapshot...
del eng                                  # ...and "crash"

resumed = ServingEngine(model, params, max_batch=4, max_seq=256,
                        kv_dtype=jnp.float32, compute_dtype=jnp.float32)
step = resumed.restore(snapdir)          # fingerprint-validated
got2 = {r.uid: r.out_tokens
        for r in early + resumed.run_until_done()}
assert got2 == want, "restored run diverged from the reference!"
print(f"crash-safe serving: snapshot at step {step}, restored engine "
      f"finished all {len(got2)} streams token-identically")
