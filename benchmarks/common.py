"""Shared benchmark machinery mirroring the paper's §3 methodology.

"the program iterates ten times through allocating memory, writing some
data, checking that the data is correct when read back and then freeing
the memory.  The average time for performing the allocations and frees
is calculated ... the code was modified to report the average over all
iterations, and the average over all but the first iteration"

The JIT parallel holds exactly: XLA compiles on the first call the way
SYCL JIT-compiles SPIR-V, so ``avg_all`` vs ``avg_subsequent`` is the
same apples-to-apples split the paper added.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros

ITERS = 10
BENCH_HEAP = HeapConfig(total_bytes=32 << 20, chunk_bytes=8 << 10,
                        min_page_bytes=16)


def bench_variant(variant: str, *, n_allocs: int, size_bytes: int,
                  iters: int = ITERS, cfg: HeapConfig = BENCH_HEAP,
                  backend: str = "jnp", lowering: str = "auto",
                  num_shards: int = 1):
    """One paper-style measurement cell.  Returns dict with avg_all /
    avg_subsequent alloc+free µs and the data-integrity flag.

    ``backend`` selects the transaction implementation (jnp reference
    vs fused Pallas kernels) so every figure cell can report the two
    side by side — on CPU the Pallas path runs in interpret mode, so
    its timings are only meaningful on a TPU backend.  ``lowering``
    picks the Pallas kernel shape (whole-arena refs vs region-blocked;
    kernels/ops.resolve_lowering).  ``num_shards`` runs the cell on the
    sharded multi-arena allocator (core/shards.py): hashed home-shard
    routing, full overflow walk — the scaling axis of the shard
    sweep."""
    ouro = Ouroboros(cfg, variant, backend, lowering,
                     num_shards=num_shards)
    state = ouro.init()
    jax.block_until_ready(state)
    sizes = jnp.full(n_allocs, size_bytes, jnp.int32)
    mask = jnp.ones(n_allocs, bool)
    tags = jnp.arange(n_allocs, dtype=jnp.int32)

    alloc_t, free_t = [], []
    all_ok = True
    for it in range(iters):
        t0 = time.perf_counter()
        state, offs = ouro.alloc(state, sizes, mask)
        jax.block_until_ready(offs)
        alloc_t.append(time.perf_counter() - t0)

        state = ouro.write_pattern(state, offs, sizes, tags)
        ok = np.asarray(ouro.check_pattern(state, offs, sizes, tags))
        granted = np.asarray(offs) >= 0
        all_ok &= bool(ok[granted].all()) and bool(granted.any())

        t0 = time.perf_counter()
        state = ouro.free(state, offs, sizes, mask)
        jax.block_until_ready(state)
        free_t.append(time.perf_counter() - t0)

    from repro.kernels.ops import resolve_lowering
    us = lambda ts: 1e6 * float(np.mean(ts))
    return {
        "variant": variant, "backend": backend,
        "lowering": (resolve_lowering(lowering) if backend == "pallas"
                     else "none"),
        "num_shards": num_shards,
        "n": n_allocs, "size": size_bytes,
        "alloc_us_all": us(alloc_t),
        "alloc_us_subsequent": us(alloc_t[1:]),
        "free_us_all": us(free_t),
        "free_us_subsequent": us(free_t[1:]),
        "per_alloc_ns": 1e9 * float(np.mean(alloc_t[1:])) / n_allocs,
        "data_ok": all_ok,
    }


SIZE_SWEEP = (16, 64, 256, 1024, 4096, 8192)       # paper fig x-axis 1
THREAD_SWEEP = (32, 128, 512, 1024, 4096, 8192)    # paper fig x-axis 2
THREAD_SWEEP_CHUNK = (32, 128, 512, 1024, 2048)    # chunk walk is O(N/ppc)


def figure_rows(variant: str, quick: bool = False,
                backend: str = "jnp", lowering: str = "auto",
                num_shards: int = 1):
    """The two sweeps of one paper figure (size @1024 allocs; threads
    @1000 B), as the paper's figs. 1-6 do per allocator."""
    sizes = SIZE_SWEEP[::3] if quick else SIZE_SWEEP
    is_chunk = "chunk" in variant
    threads = (THREAD_SWEEP_CHUNK if is_chunk else THREAD_SWEEP)
    threads = threads[::3] if quick else threads
    rows = []
    for s in sizes:
        rows.append(bench_variant(variant, n_allocs=1024 if not quick
                                  else 256, size_bytes=s,
                                  backend=backend, lowering=lowering,
                                  num_shards=num_shards))
    for n in threads:
        rows.append(bench_variant(variant, n_allocs=n, size_bytes=1000,
                                  backend=backend, lowering=lowering,
                                  num_shards=num_shards))
    return rows


def pallas_calls_per_txn(variant: str, backend: str = "pallas",
                         lowering: str = "auto", num_shards: int = 1):
    """(alloc, free) pallas_call launch counts for one bulk transaction,
    read off the jaxpr — the proof of single-kernel fusion the arena
    refactor claims (1/1 for "pallas" under BOTH lowerings AND any
    ``num_shards`` — the sharded schedule rides the grid — 0/0 for
    "jnp").  Uses a small heap: the count is layout-independent and
    tracing stays cheap."""
    from repro.kernels.ops import count_pallas_calls as count

    cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                     min_page_bytes=16)
    ouro = Ouroboros(cfg, variant, backend, lowering,
                     num_shards=num_shards)
    st = ouro.init()
    sizes = jnp.full(16, 64, jnp.int32)
    mask = jnp.ones(16, bool)
    offs = jnp.full(16, -1, jnp.int32)
    ja = jax.make_jaxpr(lambda s, z, m: ouro.alloc(s, z, m))(
        st, sizes, mask)
    jf = jax.make_jaxpr(lambda s, o, z, m: ouro.free(s, o, z, m))(
        st, offs, sizes, mask)
    return count(ja), count(jf)


def pallas_calls_per_defrag_wave(variant: str, backend: str = "pallas",
                                 lowering: str = "auto",
                                 num_shards: int = 1):
    """pallas_call launch count for one whole defragmentation wave —
    plan AND migrate (DESIGN.md §10) — read off the jaxpr (1 for
    "pallas" under both lowerings and any ``num_shards``, 0 for
    "jnp")."""
    from repro.kernels.ops import count_pallas_calls as count

    cfg = HeapConfig(total_bytes=num_shards << 16, chunk_bytes=1 << 11,
                     min_page_bytes=16)
    ouro = Ouroboros(cfg, variant, backend, lowering,
                     num_shards=num_shards)
    st = ouro.init()
    return count(jax.make_jaxpr(
        lambda s: ouro.defrag(s, max_moves=32))(st))


def launches_per_tick(engine) -> int:
    """pallas_call launch count of ONE decode tick, read off the
    engine's own jaxprs (the fused mega-step program, or — host mode —
    the jitted decode plus its bulk-grow transaction).  A thin
    delegate to ``ServingEngine.launches_per_tick`` — the SAME counter
    feeds ``engine.stats["launches_per_tick"]`` and the fig8 serving
    records, so the two can never disagree.  Constant in ``max_batch``
    (each tick is a fixed set of jitted programs; the grow transaction
    is a single kernel): 1 with ``alloc_backend="pallas"``, 0 with the
    jnp oracle in mega mode."""
    return engine.launches_per_tick()


def alloc_comparison_cell(variant: str, *, quick: bool = False,
                          lowering: str = "auto"):
    """One jnp-vs-pallas cell per variant for BENCH_alloc.json — the
    perf-trajectory artifact future PRs diff against."""
    n = 128 if quick else 512
    cfg = HeapConfig(total_bytes=4 << 20, chunk_bytes=8 << 10,
                     min_page_bytes=16)
    out = {}
    for backend in ("jnp", "pallas"):
        r = bench_variant(variant, n_allocs=n, size_bytes=256,
                          iters=4 if quick else ITERS, cfg=cfg,
                          backend=backend, lowering=lowering)
        out[backend] = {
            "lowering": r["lowering"],
            "alloc_us_all": r["alloc_us_all"],
            "alloc_us_subsequent": r["alloc_us_subsequent"],
            "free_us_all": r["free_us_all"],
            "free_us_subsequent": r["free_us_subsequent"],
            "data_ok": r["data_ok"],
        }
    return out


# ---------------------------------------------------------------------------
# BENCH_serve.json trajectory schema (DESIGN.md §13)
#
# The file is append-only: {"runs": [record, ...]}, one record per
# benchmark invocation.  Two record kinds share the envelope —
# ``serve`` (fig8: host/mega tokens-per-second cells) and ``replay``
# (fig9: per-scenario traffic-replay telemetry cells).  Records written
# before the ``record`` key existed are ``serve`` records; the
# validator grandfathers them in rather than rewriting history.
# ---------------------------------------------------------------------------

SERVE_RECORD_KINDS = ("serve", "replay")
SERVE_RECORD_KEYS = ("platform", "git_sha", "record", "cells")
REPLAY_CELL_KEYS = (
    "scenario", "arch", "mode", "requests", "completed", "cancelled",
    "steps", "tokens", "tick_ms_p50", "tick_ms_p99", "queue_wait_p50",
    "queue_wait_p99", "evictions", "defrag_waves", "auto_defrag_waves",
    "pages_migrated", "aux_pages_per_slot", "allocs", "frees",
    "frag_ratio_final",
) + (
    # compile-pollution split (DESIGN.md §14): ticks that paid a jit
    # first-call are summed into compile_ms and EXCLUDED from the
    # steady percentiles; the unsplit p50/p99 above keep their
    # all-ticks meaning so old records stay comparable.  Cells written
    # before the split exist in the append-only trajectory; the
    # validator grandfathers a cell carrying NONE of these three,
    # like pre-``record`` envelopes, but a cell with any must have all.
    "compile_ms", "tick_ms_p50_steady", "tick_ms_p99_steady",
)

REPLAY_STEADY_KEYS = REPLAY_CELL_KEYS[-3:]


def validate_serve_record(record) -> str:
    """Schema-check one BENCH_serve.json run record; returns its kind.

    Required envelope keys: ``platform``, ``git_sha``, a non-empty
    ``cells`` dict, and a ``record`` kind from
    :data:`SERVE_RECORD_KINDS` — absent kind means a legacy fig8
    record and validates as ``"serve"``.  ``replay`` cells must carry
    every telemetry key in :data:`REPLAY_CELL_KEYS` (the p50/p99 +
    fragmentation trajectory future PRs diff against); cells written
    before the :data:`REPLAY_STEADY_KEYS` compile split are
    grandfathered without them.  Raises ``ValueError`` with the
    offending key on any violation."""
    if not isinstance(record, dict):
        raise ValueError(f"serve record must be a dict, got "
                         f"{type(record).__name__}")
    kind = record.get("record", "serve")
    if kind not in SERVE_RECORD_KINDS:
        raise ValueError(f"unknown serve record kind {kind!r}; expected "
                         f"one of {SERVE_RECORD_KINDS}")
    for key in SERVE_RECORD_KEYS:
        if key == "record":
            continue                      # legacy records predate it
        if key not in record:
            raise ValueError(f"serve record missing required key "
                             f"{key!r} (kind={kind})")
    cells = record["cells"]
    if not isinstance(cells, dict) or not cells:
        raise ValueError(f"serve record 'cells' must be a non-empty "
                         f"dict, got {cells!r}")
    if kind == "replay":
        for name, cell in cells.items():
            required = REPLAY_CELL_KEYS
            if not any(k in cell for k in REPLAY_STEADY_KEYS):
                # a cell predating the §14 compile split: grandfather
                # it in rather than rewriting the append-only history
                required = [k for k in required
                            if k not in REPLAY_STEADY_KEYS]
            missing = [k for k in required if k not in cell]
            if missing:
                raise ValueError(f"replay cell {name!r} missing "
                                 f"telemetry keys {missing}")
    return kind


def load_runs(path: str) -> list:
    """Existing run records of an append-only trajectory file; a
    pre-append-format file (one flat jnp-vs-pallas report with
    ``_meta``) becomes run #1.  An unparseable file raises instead of
    being overwritten — the whole point of the append format is never
    to lose the trajectory."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        try:
            data = json.load(f)
        except ValueError as e:
            raise SystemExit(
                f"{path} exists but is not valid JSON ({e}); refusing "
                f"to overwrite the perf trajectory — fix or move the "
                f"file and rerun") from e
    if isinstance(data, dict) and isinstance(data.get("runs"), list):
        return data["runs"]
    if isinstance(data, dict) and "runs" in data:
        # new-format marker with a mangled value: never "migrate" it.
        raise SystemExit(
            f"{path} has a 'runs' key that is not a list; refusing to "
            f"rewrite a damaged trajectory file")
    if isinstance(data, dict) and data:
        meta = data.pop("_meta", {})
        return [{"platform": meta.get("platform", "unknown"),
                 "git_sha": "pre-append-format",
                 "quick": meta.get("quick"),
                 "variants": data}]
    raise SystemExit(
        f"{path} holds unrecognized JSON (neither a runs list nor a "
        f"legacy report); refusing to overwrite it")


def append_serve_record(path: str, record: dict) -> int:
    """Validate ``record`` and append it to the BENCH_serve.json
    trajectory at ``path`` (atomic replace — a failure mid-dump must
    not truncate the file).  Returns the new run count."""
    validate_serve_record(record)
    runs = load_runs(path)
    runs.append(record)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"runs": runs}, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return len(runs)


SHARD_SWEEP = (1, 2, 4)


def shard_scaling_cell(variant: str, *, quick: bool = False,
                       backend: str = "jnp", lowering: str = "auto"):
    """Throughput vs num_shards for one variant — the horizontal-
    scaling record appended to BENCH_alloc.json (DESIGN.md §9).  Same
    heap and request stream at every shard count, so the axis isolates
    the sharded transaction schedule.  CPU caveat: the jnp path runs
    the serial (attempt, shard) replay host-side, so CPU cells GROW
    with num_shards — they are a correctness/trajectory record; the
    scaling result itself is a TPU measurement (gridded kernels)."""
    n = 128 if quick else 512
    cfg = HeapConfig(total_bytes=4 << 20, chunk_bytes=8 << 10,
                     min_page_bytes=16)
    out = {}
    for num_shards in SHARD_SWEEP:
        r = bench_variant(variant, n_allocs=n, size_bytes=256,
                          iters=4 if quick else ITERS, cfg=cfg,
                          backend=backend, lowering=lowering,
                          num_shards=num_shards)
        out[str(num_shards)] = {
            "backend": backend,
            "lowering": r["lowering"],
            "alloc_us_subsequent": r["alloc_us_subsequent"],
            "free_us_subsequent": r["free_us_subsequent"],
            "allocs_per_s_subsequent":
                1e6 * n / max(r["alloc_us_subsequent"], 1e-9),
            "data_ok": r["data_ok"],
        }
    return out
