"""Fig. 8 (ours): end-to-end serving throughput on the paged KV cache.

The paper's figures stop at allocator microbenchmarks; this figure
closes the loop the ROADMAP's north star asks for — the allocator
inside a decode hot path.  One cell = the serving engine generating a
fixed request batch to completion on the reduced qwen2 config, reported
as tokens/second, for the host-loop decode and the fused mega-step
(serve/engine.py, DESIGN.md §11) side by side.

Methodology mirrors benchmarks/common.py: round 1 includes every jit
compile (the paper's avg-all column), round 2 replays the identical
request batch on the warm engine (avg-subsequent — the serving number
that matters).  CPU caveat as everywhere in this repo: pallas cells run
in interpret mode, so on CPU the jnp column is the perf signal and the
pallas column is a correctness/trajectory record; mega-vs-host on the
SAME backend is meaningful on both platforms.

``launches_per_tick`` rides along on EVERY cell — the launch count of
one decode tick read off the jaxprs (benchmarks/common.py delegates to
the engine, so stats and records always agree).  Mega cells count the
fused tick program; host cells count the jitted decode plus the
bulk-grow transaction dispatched around it, so host-vs-mega launch
records are directly comparable: 1 with ``alloc_backend="pallas"``
(the bulk grow transaction; attention is the jnp paged path on the
decode hot loop), 0 with the jnp oracle in mega mode, and constant in
``max_batch`` either way.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _engine(mega: bool, backend: str, lowering: str, num_shards: int,
            quick: bool):
    from repro.configs import get_arch
    from repro.models.model import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_batch=2 if quick else 4,
                        max_seq=96, kv_dtype=jnp.float32,
                        compute_dtype=jnp.float32, mega_step=mega,
                        alloc_backend=backend, alloc_lowering=lowering,
                        num_shards=num_shards)
    return cfg, eng


def _requests(cfg, quick: bool):
    rng = np.random.default_rng(0)
    n = 4 if quick else 8
    return [(rng.integers(2, cfg.vocab_size, int(rng.integers(8, 40))),
             8 if quick else 16) for _ in range(n)]


def serve_cell(*, mega: bool, backend: str = "jnp",
               lowering: str = "auto", num_shards: int = 1,
               quick: bool = False):
    """One serving-throughput measurement cell (see module doc)."""
    cfg, eng = _engine(mega, backend, lowering, num_shards, quick)
    reqs = _requests(cfg, quick)

    def one_round():
        for prompt, max_new in reqs:
            eng.submit(prompt, max_new_tokens=max_new)
        t0 = time.perf_counter()
        done = eng.run_until_done(2000)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        assert len(done) == len(reqs)
        return toks, dt

    toks1, dt1 = one_round()   # includes every jit compile
    toks2, dt2 = one_round()   # warm replay: the serving number
    row = {
        "variant": "serve/" + ("mega" if mega else "host"),
        "mode": "mega" if mega else "host",
        "backend": backend,
        "lowering": eng.stats["alloc_lowering"],
        "num_shards": num_shards,
        "n": len(reqs), "size": toks2,
        "tokens": toks2,
        "tokens_per_s_all": toks1 / max(dt1, 1e-9),
        "tokens_per_s": toks2 / max(dt2, 1e-9),
        "alloc_txns": eng.stats["alloc_txns"],
        "launches_per_tick": eng.launches_per_tick(),
    }
    return row


def run(quick: bool = False, backend: str = "jnp",
        lowering: str = "auto", num_shards: int = 1):
    """Figure rows: host-loop vs mega-step on the requested backend."""
    return [serve_cell(mega=False, backend=backend, lowering=lowering,
                       num_shards=num_shards, quick=quick),
            serve_cell(mega=True, backend=backend, lowering=lowering,
                       num_shards=num_shards, quick=quick)]


def serve_record(quick: bool = False):
    """The BENCH_serve.json cell block: host/mega on the jnp oracle
    (the CPU perf signal) plus a mega/pallas cell for the fused-kernel
    trajectory and its launches-per-tick proof."""
    cells = {
        "host/jnp": serve_cell(mega=False, backend="jnp", quick=quick),
        "mega/jnp": serve_cell(mega=True, backend="jnp", quick=quick),
        "mega/pallas": serve_cell(mega=True, backend="pallas",
                                  quick=quick),
    }
    return {k: {f: v[f] for f in ("tokens", "tokens_per_s_all",
                                  "tokens_per_s", "alloc_txns",
                                  "lowering", "launches_per_tick")}
            for k, v in cells.items()}
