"""Churn-then-defrag reclamation curve (extension — NOT a paper figure).

Drives the ``vl_chunk`` allocator (the serving engine's variant)
through alloc/free churn rounds that strand free pages inside
sparsely-occupied bound chunks, sampling the fragmentation gauges
(``free_words`` / ``largest_free_extent`` / ``frag_ratio``,
DESIGN.md §10) after each round, then runs ONE ``Ouroboros.defrag``
wave and samples again — the reclamation curve
``benchmarks/run.py --alloc-json`` appends to ``BENCH_alloc.json`` as
the ``frag_defrag`` record.

``run()`` reports the wave itself in the standard figure-row shape:
``alloc_us_*`` is the migration-wave latency (first call = compile,
subsequent = steady state), ``n`` the pages migrated per wave, and
``data_ok`` the write/read-back integrity of surviving allocations
checked THROUGH the forwarding remap.  The interpret-vs-compiled
caveat from README applies to pallas cells on CPU.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros
from repro.core import defrag as D

VARIANT = "vl_chunk"
PAGE = 64
FRAG_HEAP = HeapConfig(total_bytes=1 << 17, chunk_bytes=1 << 11,
                       min_page_bytes=PAGE)
N = 64


def _churn_round(ouro, state, rng, live):
    sizes = jnp.full(N, PAGE, jnp.int32)
    mask = jnp.asarray(rng.random(N) < 0.9)
    state, offs = ouro.alloc(state, sizes, mask)
    live.extend(int(o) for o in np.asarray(offs) if o >= 0)
    # free ~80% of everything currently live, scattered
    rng.shuffle(live)
    ndrop = int(len(live) * 0.8)
    drop, live[:] = live[:ndrop], live[ndrop:]
    for i in range(0, len(drop), N):
        b = drop[i:i + N]
        fo = np.full(N, -1, np.int32)
        fo[:len(b)] = b
        state = ouro.free(state, jnp.asarray(fo), sizes,
                          jnp.asarray(fo >= 0))
    return state


def _gauges(ouro, state):
    fs = ouro.frag_stats(state)
    return {"free_words": int(fs["free_words"]),
            "largest_free_extent": int(fs["largest_free_extent"]),
            "frag_ratio": round(float(fs["frag_ratio"]), 4)}


def reclamation_record(quick: bool = False, backend: str = "jnp",
                       lowering: str = "auto"):
    """The churn-then-defrag curve: per-round fragmentation gauges,
    then the one-wave reclamation deltas."""
    rounds = 4 if quick else 10
    ouro = Ouroboros(FRAG_HEAP, VARIANT, backend, lowering)
    state = ouro.init()
    rng = np.random.default_rng(0)
    live = []
    curve = [dict(round=0, **_gauges(ouro, state))]
    for r in range(rounds):
        state = _churn_round(ouro, state, rng, live)
        curve.append(dict(round=r + 1, **_gauges(ouro, state)))
    t0 = time.perf_counter()
    state, fwd = ouro.defrag(state)
    jax.block_until_ready(state.mem)
    wave_ms = 1e3 * (time.perf_counter() - t0)
    after = _gauges(ouro, state)
    return {
        "variant": VARIANT, "backend": backend,
        "rounds": rounds, "curve": curve,
        "pages_migrated": int((np.asarray(fwd.src) >= 0).sum()),
        "wave_ms_first": round(wave_ms, 2),
        "after_defrag": after,
    }


def run(quick: bool = False, backend: str = "jnp",
        lowering: str = "auto", num_shards: int = 1):
    """Standard figure rows for the defrag wave itself (churn → wave,
    iterated; avg-all vs avg-subsequent, paper-§3 style)."""
    iters = 3 if quick else 6
    ouro = Ouroboros(FRAG_HEAP, VARIANT, backend, lowering,
                     num_shards=num_shards)
    state = ouro.init()
    rng = np.random.default_rng(1)
    live = []
    wave_t, moved, all_ok = [], [], True
    sizes = jnp.full(N, PAGE, jnp.int32)
    for it in range(iters):
        state = _churn_round(ouro, state, rng, live)
        # tag the survivors, defrag, verify through the remap
        lanes = max(N, ((len(live) + N - 1) // N) * N)
        ko = np.full(lanes, -1, np.int32)
        ko[:len(live)] = live
        sz = jnp.full(lanes, PAGE, jnp.int32)
        tags = jnp.arange(it * lanes, (it + 1) * lanes, dtype=jnp.int32)
        state = ouro.write_pattern(state, jnp.asarray(ko), sz, tags)
        t0 = time.perf_counter()
        state, fwd = ouro.defrag(state)
        jax.block_until_ready(state.mem)
        wave_t.append(time.perf_counter() - t0)
        moved.append(int((np.asarray(fwd.src) >= 0).sum()))
        ko2 = np.asarray(D.forward_offsets(fwd, jnp.asarray(ko)))
        ok = np.asarray(ouro.check_pattern(state, jnp.asarray(ko2), sz,
                                           tags))
        all_ok &= bool(ok[:len(live)].all())
        live = [int(x) for x in ko2[:len(live)]]

    from repro.kernels.ops import resolve_lowering
    us = lambda ts: 1e6 * float(np.mean(ts))
    n_moves = max(1, int(np.mean(moved[1:]) if len(moved) > 1
                         else moved[0]))
    return [{
        "variant": VARIANT, "backend": backend,
        "lowering": (resolve_lowering(lowering) if backend == "pallas"
                     else "none"),
        "num_shards": num_shards,
        "n": n_moves, "size": PAGE,
        "alloc_us_all": us(wave_t),
        "alloc_us_subsequent": us(wave_t[1:]),
        "free_us_all": 0.0,
        "free_us_subsequent": 0.0,
        "per_alloc_ns": 1e9 * float(np.mean(wave_t[1:])) / n_moves,
        "data_ok": all_ok,
    }]
