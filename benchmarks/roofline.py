"""Roofline-term derivation from the dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

cost_analysis() on the SPMD-partitioned module is *per device*, so the
per-chip forms used here are algebraically identical (global = per-dev
× chips).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    """Spec formula: 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N·D for inference kinds (no backward)."""
    from repro.configs import SHAPES, get_arch
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyse(rec: dict) -> dict:
    chips = rec["devices"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes"]
    # recompute the total from per-kind values clamped at 0: early
    # records predate the probe-unit clamp and a negative per-layer
    # all-reduce unit could understate the stored total.
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    coll_dev = sum(max(rec["collectives"].get(k, 0), 0) for k in kinds)
    mem = rec.get("memory", {})
    t_compute = flops_dev / PEAK_FLOPS
    # Spec formula: HLO "bytes accessed".  This counts every operand of
    # every op as if it crossed HBM — VMEM-resident reuse (fusion,
    # flash blocks, scan carries) is billed repeatedly, so it
    # overestimates traffic by ~5-20×.  We report it AND a realistic
    # HBM-crossing estimate from buffer sizes: arguments read + outputs
    # written + temps written-and-read once each.
    t_memory_hlo = bytes_dev / HBM_BW
    traffic = (mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
               + 2 * mem.get("temp_bytes", 0))
    t_memory = traffic / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * chips
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "t_compute_s": t_compute, "t_memory_hlo_s": t_memory_hlo,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        # fraction of roofline: ideal(=model-flops compute time) / actual
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound
        if bound else 0.0,
        "peak_gib": mem.get("peak_bytes", 0) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(f))
        if not rec.get("ok") or "cost" not in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "error": rec.get("error")})
            continue
        rows.append(analyse(rec))
    if args.markdown:
        hdr = ("| arch | shape | mesh | tag | compute s | mem(hlo) s | "
               "mem(hbm) s | collective s | dominant | useful | roofline "
               "| peak GiB |")
        print(hdr)
        print("|" + "---|" * 12)
        for r in rows:
            if "error" in r:
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | | "
                      f"ERROR: {str(r['error'])[:60]} | | | | | | | |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {r['tag']} "
                  f"| {r['t_compute_s']:.4f} | {r['t_memory_hlo_s']:.3f} "
                  f"| {r['t_memory_s']:.4f} "
                  f"| {r['t_collective_s']:.4f} | {r['dominant']} "
                  f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
                  f"| {r['peak_gib']:.1f} |")
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
