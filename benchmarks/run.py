"""Benchmark harness entry: one module per paper figure (figs. 1-6).

Prints ``name,us_per_call,derived`` CSV as mandated — ``us_per_call`` is
the paper's headline metric (average subsequent allocation time), and
``derived`` carries the full methodology split (avg-all vs
avg-subsequent, free time, per-alloc ns, data-integrity check).

``--backend`` selects the allocator transaction implementation; with
``both``, every figure cell is reported for the jnp reference path and
the fused Pallas kernel path side by side.  ``--alloc-json PATH``
additionally writes a compact jnp-vs-pallas comparison per variant
(``BENCH_alloc.json``) so future PRs have a perf trajectory to diff
against.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--fig fig1_page]
        [--backend jnp|pallas|both] [--alloc-json BENCH_alloc.json]
"""
from __future__ import annotations

import argparse
import importlib
import json

FIGS = ["fig1_page", "fig2_chunk", "fig3_va_page", "fig4_vl_page",
        "fig5_va_chunk", "fig6_vl_chunk"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (CI)")
    ap.add_argument("--fig", action="append", default=None,
                    help="run only the named figure module(s)")
    ap.add_argument("--backend", choices=("jnp", "pallas", "both"),
                    default="jnp",
                    help="allocator transaction backend per cell")
    ap.add_argument("--alloc-json", default=None, metavar="PATH",
                    help="also write per-variant jnp-vs-pallas "
                         "avg_all/avg_subsequent to PATH")
    args = ap.parse_args(argv)
    figs = args.fig or FIGS
    backends = (("jnp", "pallas") if args.backend == "both"
                else (args.backend,))

    print("name,us_per_call,derived")
    for fig in figs:
        mod = importlib.import_module(f"benchmarks.{fig}")
        for backend in backends:
            for row in mod.run(quick=args.quick, backend=backend):
                name = (f"{fig}/{row['variant']}/{row['backend']}"
                        f"/n{row['n']}/s{row['size']}")
                derived = (f"alloc_all={row['alloc_us_all']:.0f}us "
                           f"alloc_sub={row['alloc_us_subsequent']:.0f}us "
                           f"free_sub={row['free_us_subsequent']:.0f}us "
                           f"per_alloc={row['per_alloc_ns']:.0f}ns "
                           f"data_ok={row['data_ok']}")
                print(f"{name},{row['alloc_us_subsequent']:.1f},{derived}",
                      flush=True)

    if args.alloc_json:
        import jax
        from benchmarks.common import alloc_comparison_cell
        from repro.core import VARIANTS
        report = {v: alloc_comparison_cell(v, quick=args.quick)
                  for v in VARIANTS}
        # pallas timings on a non-TPU platform are interpret-mode and
        # only the jnp column is a perf signal there; record which.
        report["_meta"] = {"platform": jax.default_backend(),
                           "quick": bool(args.quick)}
        with open(args.alloc_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.alloc_json}", flush=True)


if __name__ == "__main__":
    main()
