"""Benchmark harness entry: one module per paper figure (figs. 1-6).

Prints ``name,us_per_call,derived`` CSV as mandated — ``us_per_call`` is
the paper's headline metric (average subsequent allocation time), and
``derived`` carries the full methodology split (avg-all vs
avg-subsequent, free time, per-alloc ns, data-integrity check).

``--backend`` selects the allocator transaction implementation; with
``both``, every figure cell is reported for the jnp reference path and
the fused Pallas kernel path side by side.  ``--alloc-json PATH``
**appends** a run record — platform, git sha, per-variant jnp-vs-pallas
cells, and the pallas launches-per-transaction counts proving
single-kernel fusion — so ``BENCH_alloc.json`` accumulates a perf
trajectory across PRs instead of overwriting it (records made before
the append format are migrated in place as the first run).

Each run record also carries ``lowering: blocked|whole`` — which Pallas
kernel shape the cells ran (``--lowering``; auto = whole on CPU
interpret, blocked on TPU) — so perf rows stay comparable across the
two compiled stories.

``--num-shards`` runs the figure cells on the sharded multi-arena
allocator (core/shards.py); independently of it, every ``--alloc-json``
record now also appends a ``shard_sweep`` — throughput vs num_shards
(1, 2, 4) per swept variant — so BENCH_alloc.json tracks horizontal
scaling alongside the jnp-vs-pallas trajectory.

``--serve-json PATH`` appends a serving-throughput record (benchmarks/
fig8_serve.py): tokens/sec for the host-loop and fused mega-step decode
paths plus the launches-per-tick proof, accumulating in
``BENCH_serve.json`` with the same append-only trajectory format.
``fig8_serve`` is not in the default figure list (it builds a model);
run it with ``--fig fig8_serve`` or via ``--serve-json``.

``--fig fig9_replay`` runs the traffic-replay figure (benchmarks/
fig9_replay.py): deterministic Poisson/bursty/abandonment traces
through the serving engine for one config per model family, host and
mega decode modes parity-checked per pair; with ``--serve-json`` the
per-scenario p50/p99 + fragmentation cells append as a ``replay``
record (the ``serve``-kind fig8 record only appends when fig8 actually
ran, i.e. with no ``--fig`` filter or with ``--fig fig8_serve``).
Record schemas are validated on append (benchmarks/common.py,
``validate_serve_record``).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--fig fig1_page]
        [--backend jnp|pallas|both] [--lowering auto|whole|blocked]
        [--num-shards N] [--alloc-json BENCH_alloc.json]
        [--serve-json BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import pathlib
import subprocess
import sys

# make `python benchmarks/run.py` equivalent to
# `PYTHONPATH=src python -m benchmarks.run` — script invocation puts
# benchmarks/ (not the repo root) on sys.path.
_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

FIGS = ["fig1_page", "fig2_chunk", "fig3_va_page", "fig4_vl_page",
        "fig5_va_chunk", "fig6_vl_chunk", "fig7_frag"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (CI)")
    ap.add_argument("--fig", action="append", default=None,
                    help="run only the named figure module(s)")
    ap.add_argument("--backend", choices=("jnp", "pallas", "both"),
                    default="jnp",
                    help="allocator transaction backend per cell")
    ap.add_argument("--lowering", choices=("auto", "whole", "blocked"),
                    default="auto",
                    help="Pallas kernel lowering: whole-arena refs vs "
                         "the region-blocked compiled lowering "
                         "(DESIGN.md §8); auto picks per platform")
    ap.add_argument("--num-shards", type=int, default=1, metavar="N",
                    help="run the figure cells on the sharded "
                         "multi-arena allocator (core/shards.py, "
                         "DESIGN.md §9)")
    ap.add_argument("--alloc-json", default=None, metavar="PATH",
                    help="also write per-variant jnp-vs-pallas "
                         "avg_all/avg_subsequent to PATH")
    ap.add_argument("--serve-json", default=None, metavar="PATH",
                    help="append a serving-throughput record "
                         "(fig8_serve: host vs mega tokens/sec + "
                         "launches-per-tick) to PATH")
    args = ap.parse_args(argv)
    figs = args.fig or FIGS
    backends = (("jnp", "pallas") if args.backend == "both"
                else (args.backend,))

    print("name,us_per_call,derived")
    for fig in figs:
        mod = importlib.import_module(f"benchmarks.{fig}")
        for backend in backends:
            for row in mod.run(quick=args.quick, backend=backend,
                               lowering=args.lowering,
                               num_shards=args.num_shards):
                name = (f"{fig}/{row['variant']}/{row['backend']}"
                        f"/{row['lowering']}/sh{row['num_shards']}"
                        f"/n{row['n']}/s{row['size']}")
                if "tick_ms_p99" in row:  # replay rows (fig9_replay)
                    derived = (
                        f"p50_ms={row['tick_ms_p50']:.2f} "
                        f"p99_ms={row['tick_ms_p99']:.2f} "
                        f"wait_p50={row['queue_wait_p50']:.0f} "
                        f"wait_p99={row['queue_wait_p99']:.0f} "
                        f"done={row['completed']}/{row['requests']} "
                        f"cancelled={row['cancelled']} "
                        f"evictions={row['evictions']} "
                        f"frag={row['frag_ratio_final']:.3f}")
                    print(f"{name},{row['tick_ms_p99']:.2f},{derived}",
                          flush=True)
                    continue
                if "tokens_per_s" in row:  # serving rows (fig8_serve)
                    derived = (
                        f"tok_per_s_all={row['tokens_per_s_all']:.1f} "
                        f"tok_per_s_sub={row['tokens_per_s']:.1f} "
                        f"alloc_txns={row['alloc_txns']} "
                        f"launches_per_tick={row['launches_per_tick']}")
                    print(f"{name},{row['tokens_per_s']:.1f},{derived}",
                          flush=True)
                    continue
                derived = (f"alloc_all={row['alloc_us_all']:.0f}us "
                           f"alloc_sub={row['alloc_us_subsequent']:.0f}us "
                           f"free_sub={row['free_us_subsequent']:.0f}us "
                           f"per_alloc={row['per_alloc_ns']:.0f}ns "
                           f"data_ok={row['data_ok']}")
                print(f"{name},{row['alloc_us_subsequent']:.1f},{derived}",
                      flush=True)

    if args.alloc_json:
        import jax
        from benchmarks.common import (SHARD_SWEEP, alloc_comparison_cell,
                                       pallas_calls_per_txn,
                                       shard_scaling_cell)
        from repro.core import VARIANTS

        from repro.kernels.ops import resolve_lowering

        lowering = resolve_lowering(args.lowering)
        launches = {}
        for v in VARIANTS:
            a, f = pallas_calls_per_txn(v, "pallas", args.lowering)
            launches[v] = {"alloc": a, "free": f}
            print(f"launches_per_txn,{v}/pallas/{lowering},"
                  f"alloc={a} free={f}", flush=True)
        # the one-kernel contract holds for the sharded allocator too:
        # the (attempt, shard) schedule rides the grid, not extra
        # launches (DESIGN.md §9)
        for v in ("page", "vl_chunk"):
            a, f = pallas_calls_per_txn(v, "pallas", args.lowering,
                                        num_shards=4)
            launches[f"{v}/shards4"] = {"alloc": a, "free": f}
            print(f"launches_per_txn,{v}/pallas/{lowering}/shards4,"
                  f"alloc={a} free={f}", flush=True)
        # ...and for defragmentation waves: plan + migrate is ONE
        # launch, sharded or not (DESIGN.md §10)
        from benchmarks.common import pallas_calls_per_defrag_wave
        for v, S in (("vl_chunk", 1), ("vl_chunk", 4)):
            w = pallas_calls_per_defrag_wave(v, "pallas", args.lowering,
                                             num_shards=S)
            key = f"{v}/defrag" + (f"/shards{S}" if S > 1 else "")
            launches[key] = {"wave": w}
            print(f"launches_per_txn,{key}/pallas/{lowering},wave={w}",
                  flush=True)

        # throughput vs num_shards: the horizontal-scaling record
        # (jnp column — the CPU perf signal; see README)
        shard_sweep = {v: shard_scaling_cell(v, quick=args.quick)
                       for v in ("page", "vl_chunk")}
        for v, cells in shard_sweep.items():
            for S in SHARD_SWEEP:
                c = cells[str(S)]
                print(f"shard_sweep,{v}/jnp/shards{S},"
                      f"alloc_sub={c['alloc_us_subsequent']:.0f}us "
                      f"allocs_per_s={c['allocs_per_s_subsequent']:.0f}",
                      flush=True)

        # churn-then-defrag reclamation curve (benchmarks/fig7_frag.py,
        # DESIGN.md §10): fragmentation gauges per churn round, then
        # the one-wave reclamation deltas + wave latency
        from benchmarks import fig7_frag
        frag_defrag = fig7_frag.reclamation_record(quick=args.quick)
        print(f"frag_defrag,{frag_defrag['variant']}/jnp,"
              f"migrated={frag_defrag['pages_migrated']} "
              f"wave_ms={frag_defrag['wave_ms_first']} "
              f"frag_after={frag_defrag['after_defrag']['frag_ratio']}",
              flush=True)

        # pallas timings on a non-TPU platform are interpret-mode and
        # only the jnp column is a perf signal there; record which —
        # and which kernel lowering (whole|blocked) the pallas cells
        # actually ran, so the trajectory stays comparable.
        record = {
            "platform": jax.default_backend(),
            "git_sha": _git_sha(),
            "quick": bool(args.quick),
            "lowering": lowering,
            "launches_per_txn": launches,
            "shard_sweep": shard_sweep,
            "frag_defrag": frag_defrag,
            "variants": {v: alloc_comparison_cell(v, quick=args.quick,
                                                  lowering=args.lowering)
                         for v in VARIANTS},
        }
        from benchmarks.common import load_runs
        runs = load_runs(args.alloc_json)
        runs.append(record)
        # atomic replace: a failure mid-dump must not truncate the
        # trajectory file the append format exists to preserve.
        tmp = args.alloc_json + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"runs": runs}, f, indent=2, sort_keys=True)
        os.replace(tmp, args.alloc_json)
        print(f"appended run {len(runs)} to {args.alloc_json}", flush=True)

    if args.serve_json:
        import jax
        from benchmarks.common import append_serve_record

        # which record kinds this invocation actually measured: fig8's
        # serve record unless a --fig filter excluded it, fig9's replay
        # record only when explicitly requested (it builds a model per
        # family).
        envelope = lambda: {"platform": jax.default_backend(),
                            "git_sha": _git_sha(),
                            "quick": bool(args.quick)}
        if args.fig is None or "fig8_serve" in figs:
            from benchmarks import fig8_serve

            cells = fig8_serve.serve_record(quick=args.quick)
            for name, c in cells.items():
                print(f"serve,{name},"
                      f"tok_per_s_sub={c['tokens_per_s']:.1f} "
                      f"launches_per_tick={c['launches_per_tick']}",
                      flush=True)
            n = append_serve_record(args.serve_json, dict(
                envelope(), record="serve", cells=cells))
            print(f"appended serve run {n} to {args.serve_json}",
                  flush=True)
        if "fig9_replay" in figs:
            from benchmarks import fig9_replay

            cells = fig9_replay.replay_record(quick=args.quick)
            for name, c in cells.items():
                print(f"replay,{name},p99_ms={c['tick_ms_p99']:.2f} "
                      f"done={c['completed']}/{c['requests']} "
                      f"frag={c['frag_ratio_final']:.3f}", flush=True)
            n = append_serve_record(args.serve_json, dict(
                envelope(), record="replay", cells=cells))
            print(f"appended replay run {n} to {args.serve_json}",
                  flush=True)


def _git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


if __name__ == "__main__":
    main()
