"""Benchmark harness entry: one module per paper figure (figs. 1-6).

Prints ``name,us_per_call,derived`` CSV as mandated — ``us_per_call`` is
the paper's headline metric (average subsequent allocation time), and
``derived`` carries the full methodology split (avg-all vs
avg-subsequent, free time, per-alloc ns, data-integrity check).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--fig fig1_page]
"""
from __future__ import annotations

import argparse
import importlib
import sys

FIGS = ["fig1_page", "fig2_chunk", "fig3_va_page", "fig4_vl_page",
        "fig5_va_chunk", "fig6_vl_chunk"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (CI)")
    ap.add_argument("--fig", action="append", default=None,
                    help="run only the named figure module(s)")
    args = ap.parse_args(argv)
    figs = args.fig or FIGS

    print("name,us_per_call,derived")
    for fig in figs:
        mod = importlib.import_module(f"benchmarks.{fig}")
        for row in mod.run(quick=args.quick):
            name = (f"{fig}/{row['variant']}"
                    f"/n{row['n']}/s{row['size']}")
            derived = (f"alloc_all={row['alloc_us_all']:.0f}us "
                       f"alloc_sub={row['alloc_us_subsequent']:.0f}us "
                       f"free_sub={row['free_us_subsequent']:.0f}us "
                       f"per_alloc={row['per_alloc_ns']:.0f}ns "
                       f"data_ok={row['data_ok']}")
            print(f"{name},{row['alloc_us_subsequent']:.1f},{derived}",
                  flush=True)


if __name__ == "__main__":
    main()
