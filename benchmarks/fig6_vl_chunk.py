"""Virtualized-list chunk allocator (paper fig. 6): average subsequent allocation time as a function of
allocation size (1024 simultaneous allocations) and of the number of
simultaneous allocations (1000 B) — TPU-adapted per DESIGN.md §2 (the
"simultaneous threads" axis is the bulk-transaction lane count)."""
from benchmarks.common import figure_rows

VARIANT = "vl_chunk"


def run(quick: bool = False, backend: str = "jnp",
        lowering: str = "auto", num_shards: int = 1):
    return figure_rows(VARIANT, quick=quick, backend=backend,
                       lowering=lowering, num_shards=num_shards)
