"""Fig. 9 (ours): traffic replay across the config zoo.

The paper's thesis is ONE dynamic allocator for heterogeneous
workloads; figs. 1-8 measure it on microbenchmarks and a single dense
decode path.  This figure drives the serving engine through realistic
traffic (serve/replay.py: Poisson arrivals, bursty spikes, mixed
length distributions, client abandonment) for one representative
config per model family — dense, MoE, SSM, enc-dec, and (full grid)
hybrid-recurrent and vision-language — with the per-modality page
policy routing SSM-state and MoE expert-buffer pages through the SAME
Ouroboros arena as KV pages (paged/kv_cache.modality_page_quota).

Every cell is a *pair*: the identical trace replays on the host decode
loop and the fused mega-step, token-for-token parity and end-state
allocator conservation are asserted inside (serve/replay.replay_pair),
and BOTH modes' telemetry is reported — p50/p99 tick latency, queue
wait, evictions, and the fragmentation/defrag trajectory.  A benchmark
row that prints has therefore already passed the engine's hardest
correctness check.

Not in the default figure list (it builds a model per family); run it
with ``--fig fig9_replay``, and add ``--serve-json BENCH_serve.json``
to append the cells as a ``replay`` record to the serving trajectory
(benchmarks/common.py schema helpers).  CPU caveat as everywhere:
tick-latency percentiles are trajectory records on CPU, perf signals
on a TPU backend.
"""
from __future__ import annotations

import dataclasses

#: family → representative arch.  The quick (CI nightly) grid replays
#: the first QUICK_FAMILIES families; the full grid replays all six.
FAMILIES = (
    ("dense", "qwen2-0.5b"),
    ("ssm", "mamba2-780m"),
    ("moe", "mixtral-8x7b"),
    ("encdec", "seamless-m4t-large-v2"),
    ("hybrid", "recurrentgemma-9b"),
    ("vlm", "qwen2-vl-2b"),
)
QUICK_FAMILIES = 2          # dense + one SSM: the nightly smoke pair
SCENARIO_NAMES = ("bursty", "abandon")
QUICK_SCENARIOS = ("steady", "abandon")


def _grid(quick: bool):
    fams = FAMILIES[:QUICK_FAMILIES] if quick else FAMILIES
    scs = QUICK_SCENARIOS if quick else SCENARIO_NAMES
    return fams, scs


_CELL_CACHE = {}        # the CSV rows and the --serve-json record
                        # share one grid computation per invocation


def replay_cells(quick: bool = False, backend: str = "jnp",
                 lowering: str = "auto", num_shards: int = 1):
    """cell name ``family/arch/scenario/mode`` → telemetry summary
    (serve/replay.ReplayResult.summary), for every (family, scenario)
    in the grid, both decode modes.  Parity + conservation asserted
    per pair before its cells are admitted."""
    from repro.serve.replay import (SCENARIOS, engine_factory,
                                    generate_trace, replay_pair)

    key = (quick, backend, lowering, num_shards)
    if key in _CELL_CACHE:
        return _CELL_CACHE[key]
    fams, scs = _grid(quick)
    cells = {}
    for fi, (family, arch) in enumerate(fams):
        cfg, make = engine_factory(arch)
        kw = dict(alloc_backend=backend, alloc_lowering=lowering,
                  num_shards=num_shards)
        for si, name in enumerate(scs):
            sc = SCENARIOS[name]
            if quick:
                sc = dataclasses.replace(sc, n_requests=min(
                    sc.n_requests, 8))
            trace = generate_trace(sc, seed=101 * fi + si,
                                   vocab_size=cfg.vocab_size)
            host, mega = replay_pair(make(mega=False, **kw),
                                     make(mega=True, **kw),
                                     trace, scenario=name)
            for r in (host, mega):
                s = r.summary()
                s["family"] = family
                cells[f"{family}/{arch}/{name}/{r.mode}"] = s
    _CELL_CACHE[key] = cells
    return cells


def run(quick: bool = False, backend: str = "jnp",
        lowering: str = "auto", num_shards: int = 1):
    """Figure rows for benchmarks/run.py's CSV printer: one row per
    (family, scenario, mode) cell, ``us_per_call`` column = p99 tick
    latency in ms (the tail is the serving headline, not the mean)."""
    rows = []
    for name, cell in replay_cells(quick=quick, backend=backend,
                                   lowering=lowering,
                                   num_shards=num_shards).items():
        rows.append({
            "variant": f"replay/{name}",
            "backend": backend,
            "lowering": lowering,
            "num_shards": num_shards,
            "n": cell["requests"],
            "size": cell["tokens"],
            **cell,
        })
    return rows


def replay_record(quick: bool = False):
    """The BENCH_serve.json ``replay`` cell block (jnp oracle — the
    CPU-meaningful column; pallas replays are covered by the engine's
    backend-parity tests)."""
    return replay_cells(quick=quick, backend="jnp")
