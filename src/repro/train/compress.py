"""Gradient compression for the slow cross-pod (DCN) hop.

Within a pod, FSDP gradient reduce-scatters ride the fast ICI links and
GSPMD fuses them into the backward pass — nothing to compress.  *Across
pods*, the DCN hop is an order of magnitude slower, so the train step
optionally performs the cross-pod gradient mean as an explicit int8
all-to-all with error feedback (1-bit-Adam-style residual carrying):

    q, new_err = quantize(g + err);   g_synced = dequant(psum_int8(q))

4× fewer DCN bytes per step; the quantization residual is replayed into
the next step so the long-run gradient estimate stays unbiased.
Validated in tests/test_train.py against the uncompressed mean.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    err: Any  # pytree like grads, f32 residuals


def init_ef(params) -> EFState:
    return EFState(err=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, err, axis_name):
    """Per-leaf compressed psum-mean over ``axis_name`` (inside
    shard_map).  int8 payload crosses the wire; accumulation is f32 via
    per-shard scales gathered alongside (tiny)."""
    g = g.astype(jnp.float32) + err
    q, scale = _quantize(g)
    new_err = g - _dequant(q, scale)
    # all_gather int8 + scales, accumulate in f32 (int8 psum would wrap)
    qs = jax.lax.all_gather(q, axis_name)           # (pods, ...)
    scales = jax.lax.all_gather(scale, axis_name)   # (pods,)
    n = qs.shape[0]
    summed = jnp.tensordot(scales,
                           qs.astype(jnp.float32).reshape(n, -1),
                           axes=1).reshape(g.shape)
    return (summed / n).astype(g.dtype), new_err


def compressed_pmean(grads, ef: EFState, axis_name: str):
    """Tree-wide compressed mean + error-feedback update."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.err)
    out, errs = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = compress_leaf(g, e, axis_name)
        out.append(s.astype(g.dtype))
        errs.append(ne)
    return (jax.tree.unflatten(treedef, out),
            EFState(err=jax.tree.unflatten(treedef, errs)))


def plain_pmean(grads, axis_name: str):
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
