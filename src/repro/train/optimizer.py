"""AdamW with global-norm clipping and cosine schedule — pure JAX.

Optimizer state mirrors the param pytree (m, v per leaf) so it inherits
the params' FSDP×TP shardings leaf-for-leaf under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def lr(self, step):
        step = step.astype(jnp.float32)
        warm = self.peak_lr * (step + 1) / max(self.warmup_steps, 1)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * self.peak_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < self.warmup_steps, warm, cos)

    def init(self, params) -> AdamWState:
        zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                          v=zeros(params))

    def update(self, grads, state: AdamWState, params):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(state.step)

        def upd(g, m, v, p):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            new_p = p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                              + self.weight_decay * p)
            return new_p, m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
