"""Train step: loss → grads → AdamW, with microbatch gradient
accumulation, remat policy, activation-sharding rules, and the optional
compressed cross-pod gradient sync (train/compress.py).

The returned ``train_step(state, batch)`` is pjit-ready: callers supply
in/out shardings from ShardingRules; inside, ``use_rules`` is active
during tracing so the model's ``constrain`` hooks annotate activations
(batch→DP, seq→model: Megatron-style sequence parallelism at the
residual boundaries).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardingRules, use_rules
from repro.train import compress as C
from repro.train.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[C.EFState] = None  # error feedback (compressed sync)


def init_state(model, key, opt: AdamW, compress: bool = False
               ) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=opt.init(params),
                      ef=C.init_ef(params) if compress else None)


def abstract_state(model, opt: AdamW, compress: bool = False) -> TrainState:
    params = model.abstract_params()
    sds = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), t)
    zeros_like = sds(params)
    return TrainState(
        params=params,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=zeros_like, v=sds(params)),
        ef=C.EFState(err=jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params))
        if compress else None)


def state_logical_axes(model, compress: bool = False):
    ax = model.logical_axes()
    return TrainState(
        params=ax,
        opt=AdamWState(step=(), m=ax, v=ax),
        ef=C.EFState(err=ax) if compress else None)


def _split_micro(batch, k):
    """Split every batch leaf (batch-first by convention) into k
    microbatches along axis 0."""
    return jax.tree.map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)


def make_train_step(model, opt: AdamW, *, remat_policy: str = "full",
                    microbatches: int = 1,
                    rules: Optional[ShardingRules] = None,
                    cross_pod_compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat_policy=remat_policy)
        return loss, metrics

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        micro = _split_micro(batch, microbatches)

        def acc(carry, mb):
            gsum, lsum = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        from repro.models.layers import scan_unroll
        (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)), micro,
                                       unroll=scan_unroll())
        k = float(microbatches)
        grads = jax.tree.map(lambda g: (g / k), gsum)
        loss = lsum / k
        return loss, {"ce": loss, "aux": jnp.float32(0),
                      "tokens": jnp.float32(0)}, grads

    def train_step(state: TrainState, batch):
        with use_rules(rules):
            loss, metrics, grads = grads_of(state.params, batch)
        ef = state.ef
        if cross_pod_compress and ef is not None:
            grads, ef = _cross_pod_sync(grads, ef, rules)
        params, opt_state, om = opt.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(params=params, opt=opt_state, ef=ef), metrics

    return train_step


def _cross_pod_sync(grads, ef, rules):
    """Compressed mean over the 'pod' mesh axis via shard_map (manual
    over 'pod', auto over data/model)."""
    mesh = rules.mesh
    if "pod" not in mesh.axis_names or mesh.shape["pod"] == 1:
        return grads, ef
    from jax.sharding import PartitionSpec as P
    from functools import partial

    def sync(g, e):
        return C.compressed_pmean(g, C.EFState(err=e), "pod")

    specs_g = jax.tree.map(lambda _: P(), grads)
    fn = jax.shard_map(
        lambda g, e: sync(g, e),
        mesh=mesh,
        in_specs=(specs_g, specs_g),
        out_specs=(specs_g, C.EFState(err=specs_g)),
        check_vma=False,
        axis_names={"pod"},
    )
    out, ef2 = fn(grads, ef.err)
    return out, ef2


__all__ = ["TrainState", "init_state", "abstract_state",
           "state_logical_axes", "make_train_step", "AdamW"]
