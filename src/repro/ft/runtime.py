"""Fault-tolerance runtime: preemption, stragglers, elastic rescale.

At 1000+ nodes, failures are the steady state, not the exception.  The
pieces here are the single-controller-visible halves of the story (the
cluster manager owns the other half):

- ``PreemptionGuard`` — SIGTERM/SIGINT → finish the current step, force
  a checkpoint, exit clean.  The standard TPU-preemption dance.
- ``StepMonitor`` — per-step wall-time EWMA + outlier detection.  On a
  real multi-host deployment the per-host step times come back through
  the same allgather that syncs the loss; a host whose EWMA exceeds
  ``threshold``× the fleet median is flagged for the scheduler to
  replace (straggler mitigation by eviction, the approach that works at
  scale — speculative re-execution wastes accelerators).
- ``elastic_rescale`` — re-shard a restored TrainState onto a smaller
  (or larger) surviving mesh: shardings are re-derived from the same
  logical axes, so any mesh whose axes divide the dims works.  Paired
  with checkpoint.restore(shardings=...) this is checkpoint-restart
  elasticity; global batch is preserved by raising grad-accumulation
  (launch/train.py --microbatches scales automatically).
"""
from __future__ import annotations

import collections
import signal
import time
from typing import Optional

import jax
import numpy as np

from repro.parallel.sharding import ShardingRules


class PreemptionGuard:
    """SIGTERM-safe training: loop asks ``should_stop`` each step."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # not main thread (tests)

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StepMonitor:
    """EWMA step-time tracking + straggler flagging.

    With a ``registry`` (obs/metrics.py), every :meth:`stop` also
    publishes through it — a step-time histogram
    (``repro_step_time_ms``), the EWMA gauge, and a straggler-flag
    counter — so training loops and the serving replay export through
    the same funnel (DESIGN.md §14)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 1.5,
                 warmup: int = 2, registry=None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.registry = registry
        self.ewma: Optional[float] = None
        self.history = collections.deque(maxlen=512)
        self._count = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> dict:
        if self._t0 is None:
            raise RuntimeError(
                "StepMonitor.stop() without a matching start()")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._count += 1
        self.history.append(dt)
        straggler = False
        if self._count > self.warmup:  # skip compile steps
            if self.ewma is None:
                # seed from the warmup history (median — robust to the
                # compile-step outlier), not from this measurement: an
                # EWMA seeded from the step it judges can never flag it
                prior = list(self.history)[:-1]
                self.ewma = float(np.median(prior)) if prior else dt
            straggler = dt > self.threshold * self.ewma
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if self.registry is not None:
            self.registry.histogram(
                "repro_step_time_ms",
                "wall-clock per monitored step").observe(1e3 * dt)
            self.registry.counter(
                "repro_steps_total", "monitored steps").inc()
            if self.ewma is not None:
                self.registry.gauge(
                    "repro_step_time_ewma_ms",
                    "EWMA step time (post-warmup)").set(1e3 * self.ewma)
            if straggler:
                self.registry.counter(
                    "repro_straggler_flags_total",
                    "steps flagged above threshold x EWMA").inc()
        return {"step_time": dt, "ewma": self.ewma,
                "straggler": straggler}

    def fleet_report(self, per_host_times: np.ndarray) -> np.ndarray:
        """Multi-host: flag hosts above threshold × fleet median.
        ``per_host_times``: (hosts,) from the metrics allgather."""
        med = np.median(per_host_times)
        return per_host_times > self.threshold * med


def elastic_rescale(state, old_rules: ShardingRules,
                    new_rules: ShardingRules, logical_axes,
                    abstract_tree):
    """Re-shard a live TrainState onto a new mesh (device loss/gain).

    Works on addressable arrays (single-controller / tests) by
    device_put with the re-derived shardings."""
    shardings = new_rules.param_shardings(logical_axes, abstract_tree)

    def move(x, sh):
        if x is None:
            return None
        return jax.device_put(np.asarray(jax.device_get(x)), sh)

    return jax.tree.map(move, state, shardings,
                        is_leaf=lambda x: x is None)
