"""Continuous-batching serving engine on the Ouroboros paged KV cache.

The end-to-end integration of the paper's allocator with a model
server: sequences arrive, get admitted into free batch slots, grow
their KV page-by-page out of the allocator (bulk device transactions —
one ``alloc`` per engine step covers every growing sequence, the
lane-aggregated pattern from DESIGN.md §2), and release every page on
completion.  Page churn across requests of different lengths is exactly
the fragmentation workload Ouroboros was built for; the default
``vl_chunk`` variant claims heap chunks lazily and reuses freed pages.

Single-host reference implementation (the dry-run serve_step covers the
multi-pod path); everything device-side is jitted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.paged import kv_cache as KV


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, num_pages: Optional[int] = None,
                 kv_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                 sample: str = "greedy", alloc_backend: str = "jnp",
                 alloc_lowering: str = "auto", num_shards: int = 1,
                 rebalance_threshold: Optional[int] = None):
        # Validate the allocator knobs before any expensive setup: a
        # typo like alloc_backend="palas" must fail here with the menu
        # of choices, not surface later (or worse, quietly behave like
        # a different configuration).
        from repro.core import BACKENDS, LOWERINGS
        if alloc_backend not in BACKENDS:
            raise ValueError(
                f"unknown alloc_backend {alloc_backend!r}; pick from "
                f"{BACKENDS}")
        if alloc_lowering not in LOWERINGS:
            raise ValueError(
                f"unknown alloc_lowering {alloc_lowering!r}; pick from "
                f"{LOWERINGS}")
        if not isinstance(num_shards, int) or num_shards < 1:
            raise ValueError(
                f"num_shards must be a positive int, got {num_shards!r}")
        if rebalance_threshold is not None:
            if num_shards == 1:
                raise ValueError(
                    "rebalance_threshold requires num_shards > 1")
            if (not isinstance(rebalance_threshold, int)
                    or rebalance_threshold < 1):
                raise ValueError(
                    f"rebalance_threshold must be None or a positive "
                    f"int (pages of max-min shard imbalance), got "
                    f"{rebalance_threshold!r}")
        cfg = model.cfg
        self.model, self.params, self.cfg = model, params, cfg
        self.max_batch, self.max_seq = max_batch, max_seq
        self.page = KV.PAGE_SIZE
        self.pps = -(-max_seq // self.page)
        self.num_pages = num_pages or max_batch * self.pps
        assert sample == "greedy"

        # --- the paper's allocator manages the page-id space -------------
        # alloc_state is the flat device-resident arena (core/arena.py:
        # one word image + one control block); alloc_backend="pallas"
        # makes every bulk grant/release below a single fused kernel
        # launch (vl segment walk included), bit-identical to "jnp".
        # num_shards > 1 splits the page space into independent arenas
        # (core/shards.py): each sequence slot homes on slot % S, and
        # exhausted shards overflow to neighbors inside the same single
        # kernel launch.
        self.num_shards = num_shards
        self.rebalance_threshold = rebalance_threshold
        self.ouro, self.wpp, physical_pages = KV.make_kv_allocator(
            self.num_pages, backend=alloc_backend,
            lowering=alloc_lowering, num_shards=num_shards)
        self.alloc_state = self.ouro.init()
        self.page_bytes = 256  # logical bytes per page in the heap
        self._shard_words = (self.ouro.layout.shard_words
                             if num_shards > 1
                             else self.ouro.cfg.total_words)
        self._shard_pages = np.zeros(num_shards, np.int64)  # live/shard

        # the page array is sized by the heap's PHYSICAL page space:
        # segment-occupied chunks make granted ids sparse in it.
        self.caches = model.make_decode_caches(
            max_batch, max_seq=max_seq, kv_dtype=kv_dtype,
            num_pages=physical_pages)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.slot_len = np.zeros(max_batch, np.int64)  # host truth
        self.waiting: List[Request] = []
        self._uid = 0
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, remat_policy="none",
                                          dtype=compute_dtype))
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c,
                                              dtype=compute_dtype))
        from repro.kernels.ops import resolve_lowering
        mem_words = int(np.prod(self.alloc_state.mem.shape))
        ctl_words = int(np.prod(self.alloc_state.ctl.shape))
        self.stats = {"allocs": 0, "frees": 0, "steps": 0,
                      "alloc_failures": 0,
                      # observability: device words the arena occupies,
                      # and which transaction path actually runs
                      "arena_mem_words": mem_words,
                      "arena_ctl_words": ctl_words,
                      "alloc_backend": alloc_backend,
                      "alloc_lowering": (resolve_lowering(alloc_lowering)
                                         if alloc_backend == "pallas"
                                         else "none"),
                      # sharding observability: live pages per shard and
                      # how many grants landed off their home shard
                      # (the overflow walk at work)
                      "num_shards": num_shards,
                      "shard_pages_live": [0] * num_shards,
                      "alloc_overflows": 0,
                      # defragmentation observability (DESIGN.md §10):
                      # transactions issued, waves run, pages moved
                      "alloc_txns": 0,
                      "defrag_waves": 0,
                      "rebalance_waves": 0,
                      "pages_migrated": 0}
        self.refresh_frag_stats()

    # ---- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new_tokens=32, eos_id=None) -> int:
        self._uid += 1
        self.waiting.append(Request(self._uid, np.asarray(prompt, np.int32),
                                    max_new_tokens, eos_id))
        return self._uid

    def _kv(self):
        c = self.caches
        return c.self_kv if self.cfg.is_encdec else c.kv

    def _set_kv(self, kv):
        if self.cfg.is_encdec:
            self.caches = self.caches._replace(self_kv=kv)
        else:
            self.caches = self.caches._replace(kv=kv)

    def _bulk_alloc(self, homes: List[int]) -> List[int]:
        """ONE allocator transaction granting one page per entry of
        ``homes`` (the requesting slot's home shard — grants overflow
        to neighbor shards when that shard is full).  Lanes from
        different slots coalesce into this single kernel launch: a
        decode step issues at most one transaction for the whole
        batch."""
        n_pages = len(homes)
        lanes = max(self.max_batch * 2, n_pages)
        sizes = jnp.full(lanes, self.page_bytes, jnp.int32)
        mask = jnp.arange(lanes) < n_pages
        home = np.zeros(lanes, np.int32)
        home[:n_pages] = homes
        self.stats["alloc_txns"] += 1
        if self.num_shards > 1:
            self.alloc_state, offs = self.ouro.alloc(
                self.alloc_state, sizes, mask,
                shard_hint=jnp.asarray(home))
        else:
            self.alloc_state, offs = self.ouro.alloc(self.alloc_state,
                                                     sizes, mask)
        offs = np.asarray(offs[:n_pages])
        ok = offs >= 0
        self.stats["allocs"] += int(ok.sum())
        self.stats["alloc_failures"] += int((~ok).sum())
        shard = self._note_shard_pages(offs[ok], +1)
        self.stats["alloc_overflows"] += int((shard != home[:n_pages][ok])
                                             .sum())
        return [int(o) // self.wpp if o >= 0 else -1 for o in offs]

    def _alloc_pages(self, homes: List[int]) -> List[int]:
        """Bulk page grant with defragmentation recovery: if any lane
        fails, return this transaction's partial grants, run ONE
        defrag wave (migrating stragglers together and retiring the
        emptied chunks to the pool), and retry once — the paper-regime
        alternative to dying on a fragmented heap."""
        got = self._bulk_alloc(homes)
        if all(g >= 0 for g in got):
            return got
        self._bulk_free([g for g in got if g >= 0])
        self.defrag()
        return self._bulk_alloc(homes)

    def _note_shard_pages(self, offs, delta: int):
        """Update per-shard live-page occupancy for granted/freed word
        offsets; returns their owning shards."""
        shard = offs // self._shard_words
        np.add.at(self._shard_pages, shard, delta)
        self.stats["shard_pages_live"] = [int(x) for x in
                                          self._shard_pages]
        return shard

    def _bulk_free(self, pages: List[int]):
        if not pages:
            return
        lanes = max(self.max_batch * 2, len(pages))
        offs = np.full(lanes, -1, np.int32)
        offs[:len(pages)] = np.asarray(pages, np.int32) * self.wpp
        sizes = jnp.full(lanes, self.page_bytes, jnp.int32)
        mask = jnp.asarray(offs >= 0)
        self.alloc_state = self.ouro.free(
            self.alloc_state, jnp.asarray(offs), sizes, mask)
        self.stats["frees"] += len(pages)
        self._note_shard_pages(offs[offs >= 0], -1)

    def _map_pages(self, slot: int, upto_tokens: int):
        """Grow slot's page table to cover ``upto_tokens`` positions
        (admission path; decode growth coalesces in ``step``)."""
        if self._kv() is None:  # attention-free family: O(1) state
            return True
        need = -(-upto_tokens // self.page)
        missing = need - len(self.slot_pages[slot])
        if missing <= 0:
            return True
        got = self._alloc_pages([slot % self.num_shards] * missing)
        if any(g < 0 for g in got):
            self._bulk_free([g for g in got if g >= 0])
            return False
        self._map_granted([slot] * missing, got)
        return True

    def _map_granted(self, slots: List[int], pages: List[int]):
        """Extend the slots' page tables with freshly granted page ids
        (one scatter covers every growing slot)."""
        kv = self._kv()
        cols = []
        grown: Dict[int, int] = {}
        for s in slots:
            cols.append(len(self.slot_pages[s]) + grown.get(s, 0))
            grown[s] = grown.get(s, 0) + 1
        pt = kv.page_table.at[jnp.asarray(slots, jnp.int32),
                              jnp.asarray(cols, jnp.int32)].set(
            jnp.asarray(pages, jnp.int32))
        for s, g in zip(slots, pages):
            self.slot_pages[s].append(g)
        self._set_kv(kv._replace(page_table=pt))

    # ---- defragmentation (core/defrag.py, DESIGN.md §10) -------------------

    def defrag(self) -> int:
        """Run one defragmentation wave on the KV allocator and remap
        every engine-side page reference through the forwarding table
        (KV page heaps + page tables + slot page lists).  Returns the
        number of pages migrated.  Triggered automatically on
        allocation failure; also callable by operators between
        batches."""
        self.alloc_state, fwd = self.ouro.defrag(self.alloc_state)
        moved = self._apply_forwarding(fwd)
        self.stats["defrag_waves"] += 1
        self.stats["pages_migrated"] += moved
        self.refresh_frag_stats()
        return moved

    def _maybe_rebalance(self):
        """One cross-shard rebalance wave when per-shard live pages
        diverge beyond ``rebalance_threshold`` (pages, max − min)."""
        if self.num_shards == 1 or self.rebalance_threshold is None:
            return
        live = self._shard_pages
        if int(live.max() - live.min()) <= self.rebalance_threshold:
            return
        self.alloc_state, fwd = self.ouro.rebalance(self.alloc_state)
        moved = self._apply_forwarding(fwd)
        self.stats["rebalance_waves"] += 1
        self.stats["pages_migrated"] += moved
        self.refresh_frag_stats()

    def _apply_forwarding(self, fwd) -> int:
        """Remap every page reference the engine holds through a defrag
        forwarding table: KV page heaps move rows old→new, page tables
        and ``slot_pages`` rewrite ids, per-shard occupancy follows
        pages that changed shards.  Returns pages migrated."""
        if not (np.asarray(fwd.src) >= 0).any():
            return 0
        max_span = self.ouro.cfg.words_per_chunk // self.wpp
        kv = self._kv()
        if kv is not None:
            self._set_kv(KV.apply_forwarding(kv, fwd, self.wpp,
                                             max_span=max_span))
        # host-side tables remap through the SAME page expansion the
        # KV cache used (one source of truth for extent → page math)
        sp, dp = (np.asarray(x) for x in
                  KV.forwarding_page_map(fwd, self.wpp, max_span))
        mapping: Dict[int, int] = {int(s): int(d)
                                   for s, d in zip(sp, dp) if s >= 0}
        total = len(mapping)
        for pages in self.slot_pages:
            for i, p in enumerate(pages):
                if p in mapping:
                    old_sh = p * self.wpp // self._shard_words
                    new_sh = mapping[p] * self.wpp // self._shard_words
                    if old_sh != new_sh:
                        self._shard_pages[old_sh] -= 1
                        self._shard_pages[new_sh] += 1
                    pages[i] = mapping[p]
        self.stats["shard_pages_live"] = [int(x) for x in
                                          self._shard_pages]
        return total

    def refresh_frag_stats(self):
        """Recompute fragmentation observability into ``stats``:
        ``free_words``, ``largest_free_extent``, and ``frag_ratio``
        (1 − largest/total) — per shard when ``num_shards > 1``."""
        fs = self.ouro.frag_stats(self.alloc_state)
        if self.num_shards > 1:
            self.stats["free_words"] = [
                int(x) for x in np.asarray(fs["free_words"])]
            self.stats["largest_free_extent"] = [
                int(x) for x in np.asarray(fs["largest_free_extent"])]
            self.stats["frag_ratio"] = [
                float(x) for x in np.asarray(fs["frag_ratio"])]
        else:
            self.stats["free_words"] = int(fs["free_words"])
            self.stats["largest_free_extent"] = int(
                fs["largest_free_extent"])
            self.stats["frag_ratio"] = float(fs["frag_ratio"])
        return fs

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            lp = len(req.prompt)
            if not self._map_pages(slot, lp + 1):
                self.waiting.insert(0, req)  # heap full; retry later
                break
            # single-row prefill (padded batch keeps jit cache small)
            toks = np.zeros((self.max_batch, lp), np.int32)
            toks[slot] = req.prompt
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.modality == "audio":
                batch["src_embeds"] = jnp.zeros(
                    (self.max_batch, lp, self.cfg.d_model), jnp.float32)
            kv = self._kv()
            row_mask = np.zeros(self.max_batch, bool)
            row_mask[slot] = True
            if kv is not None:
                # hide other rows' page tables so their KV writes DROP
                # (heap rows stay disjoint), and zero this row's seq_len.
                sel = jnp.asarray(row_mask)
                kv0 = kv._replace(
                    page_table=jnp.where(sel[:, None], kv.page_table, -1),
                    seq_lens=jnp.where(sel, 0, kv.seq_lens))
                caches0 = (self.caches._replace(self_kv=kv0)
                           if self.cfg.is_encdec
                           else self.caches._replace(kv=kv0))
            else:
                caches0 = self.caches
            logits, new_caches = self._prefill(self.params, batch, caches0)
            self.caches = self._merge_row(new_caches, row_mask)
            first = int(np.argmax(np.asarray(logits[slot])))
            req.out_tokens.append(first)
            self.slot_req[slot] = req
            self.slot_len[slot] = lp + 1

    def _merge_row(self, new_caches, row_mask):
        """Keep only ``row_mask`` rows from a prefill's cache updates.

        Structure-aware (never shape-guessing — num_layers can equal
        max_batch): page heaps are taken wholesale (disjoint by
        construction: other rows' tables were hidden, writes dropped);
        batch-first leaves merge on axis 0; layer-stacked state leaves
        (Lr, B, ...) merge on axis 1."""
        mask = jnp.asarray(row_mask)

        def axis0(new, old):
            if new is None or old is None:
                return new
            sel = mask.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(sel, new, old)

        def axis1(new, old):
            if new is None or old is None:
                return new
            sel = mask.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(sel, new, old)

        old = self.caches

        def merge_kv(new_kv, old_kv):
            if new_kv is None:
                return None
            return new_kv._replace(
                layers=new_kv.layers,  # wholesale: disjoint heap rows
                page_table=axis0(new_kv.page_table, old_kv.page_table),
                seq_lens=axis0(new_kv.seq_lens, old_kv.seq_lens))

        if self.cfg.is_encdec:
            return new_caches._replace(
                self_kv=merge_kv(new_caches.self_kv, old.self_kv),
                cross_k=axis1(new_caches.cross_k, old.cross_k),
                cross_v=axis1(new_caches.cross_v, old.cross_v),
                enc_valid=(axis0(new_caches.enc_valid, old.enc_valid)
                           if new_caches.enc_valid is not None
                           else old.enc_valid))
        return new_caches._replace(
            kv=merge_kv(new_caches.kv, old.kv),
            ssm_h=axis1(new_caches.ssm_h, old.ssm_h),
            ssm_conv=axis1(new_caches.ssm_conv, old.ssm_conv))

    # ---- main loop -----------------------------------------------------------
    def _grow_active(self, active: List[int]):
        """Decode-step page growth for ALL active slots as ONE bulk
        alloc transaction (previously ``_map_pages`` ran per slot — up
        to ``max_batch`` kernel launches per decode step).  Raises
        ``MemoryError`` only after a defragmentation wave failed to
        reclaim enough pages."""
        if self._kv() is None:  # attention-free family: O(1) state
            return
        slots = []
        for s in active:
            need = -(-(int(self.slot_len[s]) + 1) // self.page)
            slots.extend([s] * (need - len(self.slot_pages[s])))
        if not slots:
            return
        got = self._alloc_pages([s % self.num_shards for s in slots])
        if any(g < 0 for g in got):
            self._bulk_free([g for g in got if g >= 0])
            raise MemoryError("KV heap exhausted mid-flight")
        self._map_granted(slots, got)

    def step(self) -> List[Request]:
        """Admit, grow pages, decode one token for all active slots,
        retire finished requests.  Returns requests finished this step."""
        self._admit()
        self._maybe_rebalance()
        active = [s for s in range(self.max_batch)
                  if self.slot_req[s] is not None]
        finished = []
        if active:
            self._grow_active(active)
            toks = np.zeros((self.max_batch, 1), np.int32)
            for s in active:
                toks[s, 0] = self.slot_req[s].out_tokens[-1]
            logits, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.caches)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for s in active:
                req = self.slot_req[s]
                req.out_tokens.append(int(nxt[s]))
                self.slot_len[s] += 1
                ln = len(req.out_tokens)
                if (ln >= req.max_new_tokens
                        or (req.eos_id is not None
                            and int(nxt[s]) == req.eos_id)):
                    req.done = True
                    finished.append(req)
                    self._release(s)
        self.stats["steps"] += 1
        return finished

    def _release(self, slot: int):
        self._bulk_free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        kv = self._kv()
        if kv is not None:
            pt = kv.page_table.at[slot].set(-1)
            sl = kv.seq_lens.at[slot].set(0)
            self._set_kv(kv._replace(page_table=pt, seq_lens=sl))
        self.slot_req[slot] = None
        self.slot_len[slot] = 0

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.waiting and all(r is None for r in self.slot_req):
                break
        return out
