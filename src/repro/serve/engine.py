"""Continuous-batching serving engine on the Ouroboros paged KV cache.

The end-to-end integration of the paper's allocator with a model
server: sequences arrive, get admitted into free batch slots, grow
their KV page-by-page out of the allocator (bulk device transactions —
one ``alloc`` per engine step covers every growing sequence, the
lane-aggregated pattern from DESIGN.md §2), and release every page on
completion.  Page churn across requests of different lengths is exactly
the fragmentation workload Ouroboros was built for; the default
``vl_chunk`` variant claims heap chunks lazily and reuses freed pages.

Two decode loops share the admission/retirement machinery:

``mega_step=False`` (host loop)  one jitted decode per tick with host
    glue around it: the host computes page need per slot, issues the
    bulk grow, scatters the grants, and reads back this tick's token
    ids (the decode jit argmaxes on device, so only ``(B,)`` int32 —
    never ``(B, vocab)`` logits — crosses the boundary).

``mega_step=True`` (fused decode mega-step, DESIGN.md §11)  ONE jitted
    function per tick that (a) computes per-slot page need from
    device-resident ``lens``/``active`` state, (b) runs the bulk grow
    as the existing single-``pallas_call`` arena transaction
    (``Ouroboros.grow``), (c) scatters granted pages into the device
    page table straight from the grant words
    (``kv_cache.scatter_grant_words`` — no host-materialized table),
    (d) runs the model forward with paged attention, and (e) greedily
    samples + advances ``seq_lens``/last-token on device.  A decode
    tick is a fixed small number of launches regardless of
    ``max_batch``; the host syncs one tiny ``(B,)`` finished/failed
    flag vector per tick and touches only control-plane decisions
    (admission, retirement, and the defrag-retry on allocation
    failure, which stays host-side).

Single-host reference implementation (the dry-run serve_step covers the
multi-pod path); everything device-side is jitted.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.paged import kv_cache as KV


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (Lp,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


SNAPSHOT_VERSION = 1


def _req_to_json(r: Request) -> dict:
    return {"uid": int(r.uid),
            "prompt": [int(t) for t in np.asarray(r.prompt)],
            "max_new_tokens": int(r.max_new_tokens),
            "eos_id": None if r.eos_id is None else int(r.eos_id),
            "out_tokens": [int(t) for t in r.out_tokens],
            "done": bool(r.done)}


def _req_from_json(d: dict) -> Request:
    return Request(
        uid=int(d["uid"]),
        prompt=np.asarray(d["prompt"], np.int32),
        max_new_tokens=int(d["max_new_tokens"]),
        eos_id=None if d["eos_id"] is None else int(d["eos_id"]),
        out_tokens=[int(t) for t in d["out_tokens"]],
        done=bool(d["done"]))


class MegaState(NamedTuple):
    """Device-resident per-slot decode state — the mega-step carry.

    The host keeps cheap integer mirrors (advanced from the per-tick
    flag vector) for stats and retirement, but the device arrays are
    the truth the fused tick computes from."""
    last_tok: jnp.ndarray     # (B,) int32 — token to decode this tick
    lens: jnp.ndarray         # (B,) int32 — tokens logically generated
    page_counts: jnp.ndarray  # (B,) int32 — KV pages mapped per slot
    active: jnp.ndarray       # (B,) bool
    budget: jnp.ndarray       # (B,) int32 — new tokens still allowed
    eos: jnp.ndarray          # (B,) int32 — eos id, −1 = none
    out_buf: jnp.ndarray      # (B, cap) int32 — generated tokens
    n_out: jnp.ndarray        # (B,) int32 — tokens in out_buf


def merge_rows(cfg, new_caches, old_caches, row_mask):
    """Keep only ``row_mask`` rows from a cache update.

    Structure-aware (never shape-guessing — num_layers can equal
    max_batch): page heaps are taken wholesale (rows outside the mask
    either had their page tables hidden or their writes dropped on a
    table hole — heap rows stay disjoint); batch-first leaves merge on
    axis 0; layer-stacked state leaves (Lr, B, ...) merge on axis 1.
    Shared by the admission prefill (mask = the admitted row) and the
    mega-step (mask = slots that advanced this tick)."""
    mask = jnp.asarray(row_mask)

    def axis0(new, old):
        if new is None or old is None:
            return new
        sel = mask.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(sel, new, old)

    def axis1(new, old):
        if new is None or old is None:
            return new
        sel = mask.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(sel, new, old)

    def merge_kv(new_kv, old_kv):
        if new_kv is None:
            return None
        return new_kv._replace(
            layers=new_kv.layers,  # wholesale: disjoint heap rows
            page_table=axis0(new_kv.page_table, old_kv.page_table),
            seq_lens=axis0(new_kv.seq_lens, old_kv.seq_lens))

    old = old_caches
    if cfg.is_encdec:
        return new_caches._replace(
            self_kv=merge_kv(new_caches.self_kv, old.self_kv),
            cross_k=axis1(new_caches.cross_k, old.cross_k),
            cross_v=axis1(new_caches.cross_v, old.cross_v),
            enc_valid=(axis0(new_caches.enc_valid, old.enc_valid)
                       if new_caches.enc_valid is not None
                       else old.enc_valid))
    return new_caches._replace(
        kv=merge_kv(new_caches.kv, old.kv),
        ssm_h=axis1(new_caches.ssm_h, old.ssm_h),
        ssm_conv=axis1(new_caches.ssm_conv, old.ssm_conv))


class ServingEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, num_pages: Optional[int] = None,
                 kv_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                 sample: str = "greedy", alloc_backend: str = "jnp",
                 alloc_lowering: str = "auto", num_shards: int = 1,
                 rebalance_threshold: Optional[int] = None,
                 mega_step: bool = False, max_new_cap: int = 256,
                 defrag_threshold: Optional[float] = None,
                 defrag_check_interval: int = 1,
                 tracer: Optional[obs_trace.Tracer] = None):
        # Validate the allocator knobs before any expensive setup: a
        # typo like alloc_backend="palas" must fail here with the menu
        # of choices, not surface later (or worse, quietly behave like
        # a different configuration).
        from repro.core import BACKENDS, LOWERINGS
        if alloc_backend not in BACKENDS:
            raise ValueError(
                f"unknown alloc_backend {alloc_backend!r}; pick from "
                f"{BACKENDS}")
        if alloc_lowering not in LOWERINGS:
            raise ValueError(
                f"unknown alloc_lowering {alloc_lowering!r}; pick from "
                f"{LOWERINGS}")
        if not isinstance(num_shards, int) or num_shards < 1:
            raise ValueError(
                f"num_shards must be a positive int, got {num_shards!r}")
        if rebalance_threshold is not None:
            if num_shards == 1:
                raise ValueError(
                    "rebalance_threshold requires num_shards > 1")
            if (not isinstance(rebalance_threshold, int)
                    or rebalance_threshold < 1):
                raise ValueError(
                    f"rebalance_threshold must be None or a positive "
                    f"int (pages of max-min shard imbalance), got "
                    f"{rebalance_threshold!r}")
        if defrag_threshold is not None and not (
                0.0 < float(defrag_threshold) < 1.0):
            raise ValueError(
                f"defrag_threshold must be None or a frag_ratio in "
                f"(0, 1), got {defrag_threshold!r}")
        if not isinstance(defrag_check_interval, int) \
                or defrag_check_interval < 1:
            raise ValueError(
                f"defrag_check_interval must be a positive int (steps "
                f"between frag_ratio checks), got "
                f"{defrag_check_interval!r}")
        if not isinstance(max_new_cap, int) or max_new_cap < 1:
            raise ValueError(
                f"max_new_cap must be a positive int, got "
                f"{max_new_cap!r}")
        cfg = model.cfg
        self.model, self.params, self.cfg = model, params, cfg
        self.max_batch, self.max_seq = max_batch, max_seq
        self.page = KV.PAGE_SIZE
        self.pps = -(-max_seq // self.page)
        self.num_pages = num_pages or max_batch * self.pps
        assert sample == "greedy"
        self.compute_dtype = compute_dtype
        self.mega_step = bool(mega_step)
        self.max_new_cap = max_new_cap
        self.defrag_threshold = (None if defrag_threshold is None
                                 else float(defrag_threshold))
        self.defrag_check_interval = defrag_check_interval
        # observability (DESIGN.md §14): engine phases emit trace
        # spans through the tracer (NULL = zero-cost no-op), host-side
        # readings publish through the metrics registry
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.metrics = obs_metrics.MetricsRegistry()
        self.last_tick_compiled = False

        # --- the paper's allocator manages the page-id space -------------
        # alloc_state is the flat device-resident arena (core/arena.py:
        # one word image + one control block); alloc_backend="pallas"
        # makes every bulk grant/release below a single fused kernel
        # launch (vl segment walk included), bit-identical to "jnp".
        # num_shards > 1 splits the page space into independent arenas
        # (core/shards.py): each sequence slot homes on slot % S, and
        # exhausted shards overflow to neighbors inside the same single
        # kernel launch.
        self.num_shards = num_shards
        self.rebalance_threshold = rebalance_threshold
        self.page_bytes = 256  # logical bytes per page in the heap
        # per-modality page policy (DESIGN.md §13): SSM/recurrent state
        # and MoE expert buffers ride the SAME arena as KV pages —
        # aux_pages per slot are granted at admission and freed at
        # retirement/eviction/cancel.  0 for dense/enc-dec/vlm, so
        # those engines are sized and behave exactly as before.
        self.aux_pages = KV.modality_page_quota(cfg, self.page_bytes)
        self.ouro, self.wpp, physical_pages = KV.make_kv_allocator(
            self.num_pages + max_batch * self.aux_pages,
            backend=alloc_backend,
            lowering=alloc_lowering, num_shards=num_shards)
        self.alloc_state = self.ouro.init()
        self._shard_words = (self.ouro.layout.shard_words
                             if num_shards > 1
                             else self.ouro.cfg.total_words)
        self._shard_pages = np.zeros(num_shards, np.int64)  # live/shard

        # the page array is sized by the heap's PHYSICAL page space:
        # segment-occupied chunks make granted ids sparse in it.
        self.caches = model.make_decode_caches(
            max_batch, max_seq=max_seq, kv_dtype=kv_dtype,
            num_pages=physical_pages)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        # per-modality aux pages (SSM state / MoE expert buffers) held
        # by each admitted slot — host-side in BOTH decode modes (the
        # quota is static per arch, so nothing device-resident needed)
        self.slot_aux: List[List[int]] = [[] for _ in range(max_batch)]
        self.slot_len = np.zeros(max_batch, np.int64)  # host truth
        self.waiting: List[Request] = []
        self._uid = 0
        # admission ordinals: which active slot is YOUNGEST (the
        # eviction victim under exhaustion — it loses the least work)
        self._admit_ord = np.zeros(max_batch, np.int64)
        self._admit_counter = 0
        # both entry points argmax ON DEVICE: only (B,) int32 token ids
        # ever cross the host boundary, never (B, vocab) logits.
        self._prefill = jax.jit(
            lambda p, b, c: _tokens_of(model.prefill(
                p, b, c, remat_policy="none", dtype=compute_dtype)))
        self._decode = jax.jit(
            lambda p, t, c: _tokens_of(model.decode_step(
                p, t, c, dtype=compute_dtype)))

        # --- device-resident slot state (mega-step mode) -----------------
        if self.mega_step:
            B = max_batch
            self.mega_state = MegaState(
                last_tok=jnp.zeros(B, jnp.int32),
                lens=jnp.zeros(B, jnp.int32),
                page_counts=jnp.zeros(B, jnp.int32),
                active=jnp.zeros(B, bool),
                budget=jnp.zeros(B, jnp.int32),
                eos=jnp.full(B, -1, jnp.int32),
                out_buf=jnp.zeros((B, max_new_cap), jnp.int32),
                n_out=jnp.zeros(B, jnp.int32))
            # host mirrors, advanced from the per-tick flag vector —
            # never synced from device mid-flight
            self._pages_host = np.zeros(B, np.int64)
            self._nout_host = np.zeros(B, np.int64)
            self._fail_streak = np.zeros(B, np.int64)
        self._mega_fn = None
        self._mega = None

        from repro.kernels.ops import resolve_lowering
        mem_words = int(np.prod(self.alloc_state.mem.shape))
        ctl_words = int(np.prod(self.alloc_state.ctl.shape))
        self.stats = {"allocs": 0, "frees": 0, "steps": 0,
                      "alloc_failures": 0,
                      # observability: device words the arena occupies,
                      # and which transaction path actually runs
                      "arena_mem_words": mem_words,
                      "arena_ctl_words": ctl_words,
                      "alloc_backend": alloc_backend,
                      "alloc_lowering": (resolve_lowering(alloc_lowering)
                                         if alloc_backend == "pallas"
                                         else "none"),
                      # sharding observability: live pages per shard and
                      # how many grants landed off their home shard
                      # (the overflow walk at work)
                      "num_shards": num_shards,
                      "shard_pages_live": [0] * num_shards,
                      "alloc_overflows": 0,
                      # defragmentation observability (DESIGN.md §10):
                      # transactions issued, waves run, pages moved
                      "alloc_txns": 0,
                      # graceful degradation (DESIGN.md §12): slots
                      # evicted + requeued when defrag could not
                      # reclaim enough pages
                      "evictions": 0,
                      # client abandonment (DESIGN.md §13): requests
                      # cancelled mid-stream or in the waiting queue
                      "cancels": 0,
                      # per-modality page policy: arena pages each
                      # admitted slot holds beyond KV (0 = dense)
                      "aux_pages_per_slot": self.aux_pages,
                      "defrag_waves": 0,
                      "rebalance_waves": 0,
                      "auto_defrag_waves": 0,
                      "pages_migrated": 0,
                      # decode-loop observability (DESIGN.md §11)
                      "mega_step": self.mega_step,
                      "launches_per_tick": None,
                      # jit first-call events observed by step(): how
                      # many of this process's ticks paid a compile
                      # (the replay harness splits its latency summary
                      # on exactly this signal — DESIGN.md §14)
                      "jit_first_calls": 0}
        self.refresh_frag_stats()

    def _compile_count(self) -> int:
        """Total jit-cache entries across the jitted callables a tick
        can dispatch — engine-owned programs plus the allocator's
        class-level transaction jits — grows exactly when a tick
        traced+compiled.  (The allocator jits are shared across
        Ouroboros instances, so another engine compiling in the same
        process can mark one of our ticks "compile" — a conservative
        misclassification: it only withholds that tick from the steady
        percentiles.)"""
        fns = [self._prefill, self._decode, self._mega]
        fns += [getattr(self.ouro, nm, None) for nm in
                ("_alloc", "_free", "_alloc_sharded", "_free_sharded",
                 "_alloc_pinned", "_free_pinned")]
        return sum(fn._cache_size() for fn in fns
                   if fn is not None and hasattr(fn, "_cache_size"))

    # ---- request lifecycle -------------------------------------------------
    def submit(self, prompt, max_new_tokens=32, eos_id=None) -> int:
        if self.mega_step and max_new_tokens > self.max_new_cap:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} exceeds the mega-step "
                f"device token buffer (max_new_cap={self.max_new_cap}); "
                f"raise max_new_cap at engine construction")
        self._uid += 1
        self.waiting.append(Request(self._uid, np.asarray(prompt, np.int32),
                                    max_new_tokens, eos_id))
        return self._uid

    def _kv(self):
        c = self.caches
        return c.self_kv if self.cfg.is_encdec else c.kv

    def _set_kv(self, kv):
        if self.cfg.is_encdec:
            self.caches = self.caches._replace(self_kv=kv)
        else:
            self.caches = self.caches._replace(kv=kv)

    def _bulk_alloc(self, homes: List[int]) -> List[int]:
        """ONE allocator transaction granting one page per entry of
        ``homes`` (the requesting slot's home shard — grants overflow
        to neighbor shards when that shard is full).  Lanes from
        different slots coalesce into this single kernel launch: a
        decode step issues at most one transaction for the whole
        batch."""
        n_pages = len(homes)
        lanes = max(self.max_batch * 2, n_pages)
        sizes = jnp.full(lanes, self.page_bytes, jnp.int32)
        mask = jnp.arange(lanes) < n_pages
        home = np.zeros(lanes, np.int32)
        home[:n_pages] = homes
        self.stats["alloc_txns"] += 1
        with self.tracer.span("bulk_grow", pages=n_pages):
            if self.num_shards > 1:
                self.alloc_state, offs = self.ouro.alloc(
                    self.alloc_state, sizes, mask,
                    shard_hint=jnp.asarray(home))
            else:
                self.alloc_state, offs = self.ouro.alloc(
                    self.alloc_state, sizes, mask)
            offs = np.asarray(offs[:n_pages])
        ok = offs >= 0
        self.stats["allocs"] += int(ok.sum())
        self.stats["alloc_failures"] += int((~ok).sum())
        shard = self._note_shard_pages(offs[ok], +1)
        self.stats["alloc_overflows"] += int((shard != home[:n_pages][ok])
                                             .sum())
        return [int(o) // self.wpp if o >= 0 else -1 for o in offs]

    def _alloc_pages(self, homes: List[int]) -> List[int]:
        """Bulk page grant with defragmentation recovery: if any lane
        fails, return this transaction's partial grants, run ONE
        defrag wave (migrating stragglers together and retiring the
        emptied chunks to the pool), and retry once — the paper-regime
        alternative to dying on a fragmented heap."""
        got = self._bulk_alloc(homes)
        if all(g >= 0 for g in got):
            return got
        self._bulk_free([g for g in got if g >= 0])
        self.defrag()
        return self._bulk_alloc(homes)

    def _note_shard_pages(self, offs, delta: int):
        """Update per-shard live-page occupancy for granted/freed word
        offsets; returns their owning shards.  In mega-step mode the
        incremental count is skipped (mega grants never surface their
        offsets to the host) — occupancy is recomputed from the device
        page table instead (:meth:`_sync_shard_pages_from_table`)."""
        shard = offs // self._shard_words
        if not self.mega_step:
            np.add.at(self._shard_pages, shard, delta)
            self.stats["shard_pages_live"] = [int(x) for x in
                                              self._shard_pages]
        return shard

    def _sync_shard_pages_from_table(self):
        """Recompute per-shard live-page occupancy from the device page
        table (mega-step mode: the table is the only place the granted
        ids live).  One small (B, P) device→host read — called on
        demand (rebalance checks, stat refreshes), never per tick."""
        kv = self._kv()
        self._shard_pages[:] = 0
        if kv is not None:
            pt = np.asarray(kv.page_table)
            pages = pt[pt >= 0]
            shard = pages * self.wpp // self._shard_words
            np.add.at(self._shard_pages, shard, 1)
        for aux in self.slot_aux:  # aux pages never enter the table
            for p in aux:
                self._shard_pages[p * self.wpp // self._shard_words] += 1
        self.stats["shard_pages_live"] = [int(x) for x in
                                          self._shard_pages]

    def _bulk_free(self, pages: List[int], count_stats: bool = True):
        if not pages:
            return
        lanes = max(self.max_batch * 2, len(pages))
        offs = np.full(lanes, -1, np.int32)
        offs[:len(pages)] = np.asarray(pages, np.int32) * self.wpp
        sizes = jnp.full(lanes, self.page_bytes, jnp.int32)
        mask = jnp.asarray(offs >= 0)
        self.alloc_state = self.ouro.free(
            self.alloc_state, jnp.asarray(offs), sizes, mask)
        if count_stats:
            self.stats["frees"] += len(pages)
        self._note_shard_pages(offs[offs >= 0], -1)

    def _map_pages(self, slot: int, upto_tokens: int):
        """Grow slot's page table to cover ``upto_tokens`` positions
        (admission path; decode growth coalesces in ``step``)."""
        if self._kv() is None:  # attention-free family: O(1) state
            return True
        need = -(-upto_tokens // self.page)
        missing = need - len(self.slot_pages[slot])
        if missing <= 0:
            return True
        got = self._alloc_pages([slot % self.num_shards] * missing)
        if any(g < 0 for g in got):
            self._bulk_free([g for g in got if g >= 0])
            return False
        self._map_granted([slot] * missing, got)
        return True

    def _alloc_aux(self, slot: int) -> bool:
        """Grant the slot its per-modality aux pages (SSM state / MoE
        expert buffers — DESIGN.md §13) out of the SAME arena the KV
        pages come from: ONE bulk transaction for the whole quota.
        Partial grants are returned on failure so allocs/frees stay
        balanced."""
        if self.aux_pages == 0:
            return True
        got = self._alloc_pages([slot % self.num_shards]
                                * self.aux_pages)
        if any(g < 0 for g in got):
            self._bulk_free([g for g in got if g >= 0])
            return False
        self.slot_aux[slot] = got
        return True

    def _free_aux(self, slot: int):
        self._bulk_free(self.slot_aux[slot])
        self.slot_aux[slot] = []

    def _map_granted(self, slots: List[int], pages: List[int]):
        """Extend the slots' page tables with freshly granted page ids
        (one scatter covers every growing slot)."""
        kv = self._kv()
        cols = []
        grown: Dict[int, int] = {}
        for s in slots:
            cols.append(len(self.slot_pages[s]) + grown.get(s, 0))
            grown[s] = grown.get(s, 0) + 1
        pt = kv.page_table.at[jnp.asarray(slots, jnp.int32),
                              jnp.asarray(cols, jnp.int32)].set(
            jnp.asarray(pages, jnp.int32))
        for s, g in zip(slots, pages):
            self.slot_pages[s].append(g)
        self._set_kv(kv._replace(page_table=pt))

    # ---- defragmentation (core/defrag.py, DESIGN.md §10) -------------------

    def defrag(self) -> int:
        """Run one defragmentation wave on the KV allocator and remap
        every engine-side page reference through the forwarding table
        (KV page heaps + page tables + slot page lists).  Returns the
        number of pages migrated.  Triggered automatically on
        allocation failure and past ``defrag_threshold``; also callable
        by operators between batches."""
        with self.tracer.span("defrag_wave"):
            self.alloc_state, fwd = self.ouro.defrag(self.alloc_state)
            moved = self._apply_forwarding(fwd)
        self.stats["defrag_waves"] += 1
        self.stats["pages_migrated"] += moved
        self.refresh_frag_stats()
        return moved

    def _maybe_auto_defrag(self):
        """Fire one defragmentation wave when ``frag_ratio`` exceeds
        the configured ``defrag_threshold`` (checked every
        ``defrag_check_interval`` steps; max over shards when sharded)
        — the proactive complement to the allocation-failure retry.
        Counted separately in ``stats["auto_defrag_waves"]``."""
        if self.defrag_threshold is None:
            return
        if self.stats["steps"] % self.defrag_check_interval:
            return
        fs = self.refresh_frag_stats()
        ratio = float(np.max(np.asarray(fs["frag_ratio"])))
        if ratio > self.defrag_threshold:
            self.defrag()
            self.stats["auto_defrag_waves"] += 1

    def _maybe_rebalance(self):
        """One cross-shard rebalance wave when per-shard live pages
        diverge beyond ``rebalance_threshold`` (pages, max − min)."""
        if self.num_shards == 1 or self.rebalance_threshold is None:
            return
        if self.mega_step:
            self._sync_shard_pages_from_table()
        live = self._shard_pages
        if int(live.max() - live.min()) <= self.rebalance_threshold:
            return
        with self.tracer.span("rebalance_wave"):
            self.alloc_state, fwd = self.ouro.rebalance(self.alloc_state)
            moved = self._apply_forwarding(fwd)
        self.stats["rebalance_waves"] += 1
        self.stats["pages_migrated"] += moved
        self.refresh_frag_stats()

    def _apply_forwarding(self, fwd) -> int:
        """Remap every page reference the engine holds through a defrag
        forwarding table: KV page heaps move rows old→new, page tables
        and ``slot_pages`` rewrite ids, per-shard occupancy follows
        pages that changed shards.  Returns pages migrated.  (In
        mega-step mode the device page table is the only id holder —
        ``slot_pages`` are empty mid-flight — so the KV remap alone
        covers everything.)"""
        if not (np.asarray(fwd.src) >= 0).any():
            return 0
        max_span = self.ouro.cfg.words_per_chunk // self.wpp
        kv = self._kv()
        if kv is not None:
            self._set_kv(KV.apply_forwarding(kv, fwd, self.wpp,
                                             max_span=max_span))
        # host-side tables remap through the SAME page expansion the
        # KV cache used (one source of truth for extent → page math)
        sp, dp = (np.asarray(x) for x in
                  KV.forwarding_page_map(fwd, self.wpp, max_span))
        mapping: Dict[int, int] = {int(s): int(d)
                                   for s, d in zip(sp, dp) if s >= 0}
        total = len(mapping)
        for pages in self.slot_pages + self.slot_aux:
            for i, p in enumerate(pages):
                if p in mapping:
                    old_sh = p * self.wpp // self._shard_words
                    new_sh = mapping[p] * self.wpp // self._shard_words
                    if old_sh != new_sh:
                        self._shard_pages[old_sh] -= 1
                        self._shard_pages[new_sh] += 1
                    pages[i] = mapping[p]
        if not self.mega_step:
            self.stats["shard_pages_live"] = [int(x) for x in
                                              self._shard_pages]
        return total

    def refresh_frag_stats(self):
        """Recompute fragmentation observability into ``stats``:
        ``free_words``, ``largest_free_extent``, and ``frag_ratio``
        (1 − largest/total) — per shard when ``num_shards > 1``."""
        fs = self.ouro.frag_stats(self.alloc_state)
        if self.num_shards > 1:
            self.stats["free_words"] = [
                int(x) for x in np.asarray(fs["free_words"])]
            self.stats["largest_free_extent"] = [
                int(x) for x in np.asarray(fs["largest_free_extent"])]
            self.stats["frag_ratio"] = [
                float(x) for x in np.asarray(fs["frag_ratio"])]
        else:
            self.stats["free_words"] = int(fs["free_words"])
            self.stats["largest_free_extent"] = int(
                fs["largest_free_extent"])
            self.stats["frag_ratio"] = float(fs["frag_ratio"])
        return fs

    # ---- observability (obs/, DESIGN.md §14) -------------------------------

    def drain_telemetry(self) -> dict:
        """Decode the arena's device-side telemetry words (the ctl
        accumulators every lowering updates in-kernel) into a host
        dict ``{field: np.ndarray}`` — per-class arrays carry a
        leading shard axis when ``num_shards > 1``.  A read, not a
        reset: the device words are monotonic."""
        from repro.obs import telemetry as OT
        lay = self.ouro.layout
        if self.num_shards > 1:
            lay = lay.shard
        return OT.decode(lay, np.asarray(self.alloc_state.ctl))

    def publish_metrics(self,
                        registry: Optional[
                            obs_metrics.MetricsRegistry] = None
                        ) -> obs_metrics.MetricsRegistry:
        """Publish every host-side reading through a metrics registry
        (``self.metrics`` unless one is passed): engine stat counters,
        fragmentation gauges, and the drained in-kernel telemetry
        words, labelled by size class / shard / walk attempt.  Returns
        the registry (export with ``to_prometheus()``/``to_json()``)."""
        reg = self.metrics if registry is None else registry
        counters = ("steps", "allocs", "frees", "alloc_failures",
                    "alloc_txns", "alloc_overflows", "evictions",
                    "cancels", "defrag_waves", "rebalance_waves",
                    "auto_defrag_waves", "pages_migrated",
                    "jit_first_calls")
        for k in counters:
            reg.counter(f"repro_engine_{k}_total",
                        f"engine stats[{k!r}]").set(float(self.stats[k]))
        reg.gauge("repro_engine_waiting",
                  "requests queued for admission").set(
                      float(len(self.waiting)))
        reg.gauge("repro_engine_active_slots",
                  "batch slots decoding").set(
            float(sum(r is not None for r in self.slot_req)))
        self.refresh_frag_stats()
        for k in ("free_words", "largest_free_extent", "frag_ratio"):
            g = reg.gauge(f"repro_arena_{k}",
                          f"allocator frag_stats[{k!r}]",
                          labelnames=("shard",))
            v = self.stats[k]
            for s, x in enumerate(v if isinstance(v, list) else [v]):
                g.labels(shard=s).set(float(x))
        tele = self.drain_telemetry()
        per_class = {"t_alloc": "repro_alloc_granted_total",
                     "t_free": "repro_free_total",
                     "t_fail": "repro_alloc_failed_total",
                     "t_wrap": "repro_ring_wrap_total"}
        scalar = {"t_grow": "repro_segment_grow_total",
                  "t_shrink": "repro_segment_shrink_total",
                  "t_pool_wrap": "repro_pool_wrap_total"}
        for field, arr in tele.items():
            arr = np.atleast_2d(np.asarray(arr))   # (S, w)
            if field in per_class:
                m = reg.counter(per_class[field],
                                f"in-kernel ctl telemetry {field}",
                                labelnames=("shard", "size_class"))
                for s in range(arr.shape[0]):
                    for c in range(arr.shape[1]):
                        m.labels(shard=s, size_class=c).set(
                            float(arr[s, c]))
            elif field in scalar:
                m = reg.counter(scalar[field],
                                f"in-kernel ctl telemetry {field}",
                                labelnames=("shard",))
                for s in range(arr.shape[0]):
                    m.labels(shard=s).set(float(arr[s, 0]))
            elif field == "t_walk":
                m = reg.counter("repro_overflow_walk_served_total",
                                "lanes served per overflow-walk "
                                "attempt (in-kernel histogram)",
                                labelnames=("shard", "attempt"))
                for s in range(arr.shape[0]):
                    for a in range(arr.shape[1]):
                        m.labels(shard=s, attempt=a).set(
                            float(arr[s, a]))
        return reg

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            lp = len(req.prompt)
            if not self._alloc_aux(slot):
                self.waiting.insert(0, req)  # heap full; retry later
                break
            if not self._map_pages(slot, lp + 1):
                self._free_aux(slot)
                self.waiting.insert(0, req)  # heap full; retry later
                break
            # single-row prefill (padded batch keeps jit cache small)
            toks = np.zeros((self.max_batch, lp), np.int32)
            toks[slot] = req.prompt
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.modality == "audio":
                # FIXED encoder length: resident rows keep their cross-
                # KV through merge_rows, so every admission must produce
                # identically-shaped cross_k/cross_v — staggered prompts
                # of different lengths would otherwise be unmergeable.
                # The stub frontend is zeros; ``src_valid`` masks the
                # padding out of cross attention (kv_valid_len).
                sv = np.zeros(self.max_batch, np.int32)
                sv[slot] = lp
                batch["src_embeds"] = jnp.zeros(
                    (self.max_batch, self.max_seq, self.cfg.d_model),
                    jnp.float32)
                batch["src_valid"] = jnp.asarray(sv)
            kv = self._kv()
            row_mask = np.zeros(self.max_batch, bool)
            row_mask[slot] = True
            if kv is not None:
                # hide other rows' page tables so their KV writes DROP
                # (heap rows stay disjoint), and zero this row's seq_len.
                sel = jnp.asarray(row_mask)
                kv0 = kv._replace(
                    page_table=jnp.where(sel[:, None], kv.page_table, -1),
                    seq_lens=jnp.where(sel, 0, kv.seq_lens))
                caches0 = (self.caches._replace(self_kv=kv0)
                           if self.cfg.is_encdec
                           else self.caches._replace(kv=kv0))
            else:
                caches0 = self.caches
            with self.tracer.span("prefill", slot=slot, uid=req.uid,
                                  prompt_len=lp):
                tok_ids, new_caches = self._prefill(self.params, batch,
                                                    caches0)
            self.caches = merge_rows(self.cfg, new_caches, self.caches,
                                     row_mask)
            first = int(np.asarray(tok_ids)[slot])
            req.out_tokens.append(first)
            self.slot_req[slot] = req
            self.slot_len[slot] = lp + 1
            self._admit_counter += 1
            self._admit_ord[slot] = self._admit_counter
            if self.mega_step:
                self._mega_admit(slot, req, first)

    def _merge_row(self, new_caches, row_mask):
        """Back-compat shim over :func:`merge_rows`."""
        return merge_rows(self.cfg, new_caches, self.caches, row_mask)

    # ---- fused decode mega-step (DESIGN.md §11) ----------------------------

    def _mega_admit(self, slot: int, req: Request, first: int):
        """Push an admitted slot's control state to the device arrays.

        Page ids granted at admission already live in the device page
        table; hand ownership over entirely (``slot_pages`` is cleared
        — from here on the table row is the only id holder, pulled
        back once at retirement)."""
        npages = len(self.slot_pages[slot])
        self._pages_host[slot] = npages
        self.slot_pages[slot] = []
        self._nout_host[slot] = 1
        self._fail_streak[slot] = 0
        ms = self.mega_state
        eos = -1 if req.eos_id is None else int(req.eos_id)
        self.mega_state = MegaState(
            last_tok=ms.last_tok.at[slot].set(first),
            lens=ms.lens.at[slot].set(int(self.slot_len[slot])),
            page_counts=ms.page_counts.at[slot].set(npages),
            active=ms.active.at[slot].set(True),
            budget=ms.budget.at[slot].set(req.max_new_tokens - 1),
            eos=ms.eos.at[slot].set(eos),
            out_buf=ms.out_buf.at[slot].set(0).at[slot, 0].set(first),
            n_out=ms.n_out.at[slot].set(1))

    def _build_mega(self):
        """Trace+compile the fused decode tick: grow → scatter →
        forward → sample → advance, ONE jitted function with the whole
        carry (arena, KV caches, slot state) donated."""
        cfg = self.cfg
        model = self.model
        ouro = self.ouro
        page, page_bytes, wpp = self.page, self.page_bytes, self.wpp
        B, S = self.max_batch, self.num_shards
        lanes = B  # decode grows ≤ 1 page per slot per tick
        cap = self.max_new_cap
        dtype = self.compute_dtype
        homes = jnp.arange(B, dtype=jnp.int32) % S
        has_kv = self._kv() is not None

        def mega(params, alloc_state, caches, ms):
            kv = caches.self_kv if cfg.is_encdec else caches.kv
            if has_kv:
                # (a) per-slot page need from device-resident state
                need = jnp.maximum(
                    -(-(ms.lens + 1) // page) - ms.page_counts, 0)
                need = jnp.where(ms.active, need, 0).astype(jnp.int32)
                # (b) bulk grow: ONE arena transaction for the batch
                alloc_state, offs, l_slot, l_rank, l_mask = ouro.grow(
                    alloc_state, need, page_bytes, lanes,
                    home=homes if S > 1 else None)
                ok = l_mask & (offs >= 0)
                granted = jnp.zeros(B + 1, jnp.int32).at[
                    jnp.where(l_mask, l_slot, B)].add(
                        ok.astype(jnp.int32))[:B]
                # a slot fails the tick when ANY of its pages did —
                # its partial grants are withheld from the table and
                # reclaimed by the host-side defrag-retry path
                failed = ms.active & (granted < need)
                grant_ok = ok & ~failed[l_slot]
                # (c) grants → device page table, straight from the
                # arena word offsets (no host-materialized table)
                kv = kv._replace(page_table=KV.scatter_grant_words(
                    kv.page_table, ms.page_counts, l_slot, l_rank,
                    offs, grant_ok, wpp))
                caches = (caches._replace(self_kv=kv) if cfg.is_encdec
                          else caches._replace(kv=kv))
                new_counts = ms.page_counts + jnp.where(failed, 0, need)
            else:  # attention-free family: O(1) state, nothing to grow
                failed = jnp.zeros(B, bool)
                offs = jnp.full(lanes, -1, jnp.int32)
                l_slot = jnp.zeros(lanes, jnp.int32)
                l_mask = jnp.zeros(lanes, bool)
                new_counts = ms.page_counts
            advance = ms.active & ~failed
            # (d) model forward with paged attention; failed/inactive
            # rows write to table holes (dropped) and their cache
            # advance is masked back out below
            logits, new_caches = model.decode_step(
                params, ms.last_tok[:, None], caches, dtype=dtype)
            caches = merge_rows(cfg, new_caches, caches, advance)
            # (e) greedy sampling + seq/token advance, all on device
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            out_buf = ms.out_buf.at[
                jnp.where(advance, jnp.arange(B, dtype=jnp.int32), B),
                jnp.minimum(ms.n_out, cap - 1)].set(nxt, mode="drop")
            budget = ms.budget - advance.astype(jnp.int32)
            finished = advance & (
                (budget <= 0) | ((ms.eos >= 0) & (nxt == ms.eos)))
            ms2 = MegaState(
                last_tok=jnp.where(advance, nxt, ms.last_tok),
                lens=ms.lens + advance.astype(jnp.int32),
                page_counts=new_counts,
                active=ms.active & ~finished,
                budget=budget,
                eos=ms.eos,
                out_buf=out_buf,
                n_out=ms.n_out + advance.astype(jnp.int32))
            # the ONLY per-tick host sync: bit 0 finished, bit 1 failed
            flags = (finished.astype(jnp.uint8)
                     | (failed.astype(jnp.uint8) << 1))
            return alloc_state, caches, ms2, flags, offs, l_slot, l_mask

        self._mega_fn = mega
        self._mega = jax.jit(mega, donate_argnums=(1, 2, 3))

    def launches_per_tick(self) -> int:
        """``pallas_call`` launch count of ONE decode tick, read off
        the jaxprs (kernels/ops.count_pallas_calls — the same counter
        as the per-transaction and per-wave proofs).  Mega-step mode
        counts the single fused tick program; host mode counts the
        jitted decode plus the bulk-grow transaction issued around it
        (the same two programs ``_step_host`` dispatches).  Constant
        in ``max_batch`` by construction either way.  Recorded into
        ``stats["launches_per_tick"]``; benchmarks/
        common.launches_per_tick delegates here so fig8 records and
        engine stats can never disagree."""
        from repro.kernels.ops import count_pallas_calls
        if self.mega_step:
            if self._mega is None:
                self._build_mega()
            jx = jax.make_jaxpr(self._mega_fn)(
                self.params, self.alloc_state, self.caches,
                self.mega_state)
            n = count_pallas_calls(jx)
        else:
            toks = jnp.zeros((self.max_batch, 1), jnp.int32)
            jx = jax.make_jaxpr(
                lambda p, t, c: self.model.decode_step(
                    p, t, c, dtype=self.compute_dtype))(
                self.params, toks, self.caches)
            n = count_pallas_calls(jx)
            # the per-tick bulk grow (_bulk_alloc lane shapes)
            lanes = self.max_batch * 2
            sizes = jnp.full(lanes, self.page_bytes, jnp.int32)
            mask = jnp.arange(lanes) < 1
            if self.num_shards > 1:
                jx2 = jax.make_jaxpr(
                    lambda st, sz, m, h: self.ouro.alloc(
                        st, sz, m, shard_hint=h))(
                    self.alloc_state, sizes, mask,
                    jnp.zeros(lanes, jnp.int32))
            else:
                jx2 = jax.make_jaxpr(
                    lambda st, sz, m: self.ouro.alloc(st, sz, m))(
                    self.alloc_state, sizes, mask)
            n += count_pallas_calls(jx2)
        self.stats["launches_per_tick"] = n
        return n

    def _step_mega(self) -> List[Request]:
        """One fused decode tick + control-plane follow-up: dispatch
        the mega-step, sync the (B,) flag vector, advance the host
        mirrors, reclaim/retry on allocation failure, retire finished
        slots (the only point page ids and tokens are pulled back)."""
        active = [s for s in range(self.max_batch)
                  if self.slot_req[s] is not None]
        if not active:
            return []
        if self._mega is None:
            self._build_mega()
        (self.alloc_state, self.caches, self.mega_state, flags,
         l_offs, l_slot, l_mask) = self._mega(
            self.params, self.alloc_state, self.caches, self.mega_state)
        flags = np.asarray(flags)          # the per-tick host sync
        fin = (flags & 1) > 0
        fail = (flags & 2) > 0
        has_kv = self._kv() is not None

        # host mirrors advance deterministically from the flags — the
        # grant count is recomputed with the SAME need formula the
        # device used, so allocs/frees stay exactly balanced
        grants = 0
        for s in active:
            if fail[s]:
                self.stats["alloc_failures"] += 1
                continue
            if has_kv:
                missing = (-(-(int(self.slot_len[s]) + 1) // self.page)
                           - int(self._pages_host[s]))
                grants += max(missing, 0)
                self._pages_host[s] += max(missing, 0)
            self.slot_len[s] += 1
            self._nout_host[s] += 1
        if has_kv:
            self.stats["alloc_txns"] += 1
            self.stats["allocs"] += grants

        if fail.any():
            self._recover_failed(fail, fin, l_offs, l_slot, l_mask)
        else:
            self._fail_streak[:] = 0

        finished = []
        for s in np.nonzero(fin)[0]:
            if self.slot_req[s] is not None:  # not evicted this tick
                finished.append(self._release_mega(int(s)))
        return finished

    def _recover_failed(self, fail, fin, l_offs, l_slot, l_mask):
        """Alloc-failure path (host-side, as in the host loop): pull
        the lane arrays (failure ticks only), return the failed slots'
        partial grants to the heap, run ONE defrag wave, and let the
        next tick retry.  Two consecutive failed retries mean defrag
        cannot reclaim enough — gracefully degrade by evicting the
        youngest active slot (its pages return to the heap, its
        request requeues and replays identically under greedy decode)
        instead of killing the server with ``MemoryError``."""
        offs_h = np.asarray(l_offs)
        slot_h = np.asarray(l_slot)
        mask_h = np.asarray(l_mask)
        leaked = mask_h & (offs_h >= 0) & fail[slot_h]
        self._free_offsets(offs_h[leaked])
        self.defrag()
        self._fail_streak[fail] += 1
        self._fail_streak[~fail] = 0
        if (self._fail_streak >= 2).any():
            # slots finishing THIS tick retire (and free) right after
            # this call — evicting one would double-release it, and
            # its pages come back anyway
            victim = self._youngest_active(
                exclude=set(int(s) for s in np.nonzero(fin)[0]))
            if victim is not None:
                self._evict_slot(victim)
                self._fail_streak[:] = 0

    def _free_offsets(self, offs_words):
        """Uncounted bulk free of raw word offsets (failure recovery:
        these grants were never counted as allocs either)."""
        if len(offs_words) == 0:
            return
        self._bulk_free([int(o) // self.wpp for o in offs_words],
                        count_stats=False)

    def _release_mega(self, slot: int) -> Request:
        """Retire one finished slot: pull its token row and page-table
        row from device (the only mid-flight device→host reads besides
        the flag vector), free the pages, and zero the slot's device
        state."""
        req = self.slot_req[slot]
        n = int(self._nout_host[slot])
        buf = np.asarray(self.mega_state.out_buf[slot])
        req.out_tokens = [int(x) for x in buf[:n]]
        req.done = True
        kv = self._kv()
        if kv is not None:
            row = np.asarray(kv.page_table[slot])
            self._bulk_free([int(p) for p in row[row >= 0]])
            pt = kv.page_table.at[slot].set(-1)
            sl = kv.seq_lens.at[slot].set(0)
            self._set_kv(kv._replace(page_table=pt, seq_lens=sl))
        self._free_aux(slot)
        ms = self.mega_state
        self.mega_state = MegaState(
            last_tok=ms.last_tok.at[slot].set(0),
            lens=ms.lens.at[slot].set(0),
            page_counts=ms.page_counts.at[slot].set(0),
            active=ms.active.at[slot].set(False),
            budget=ms.budget.at[slot].set(0),
            eos=ms.eos.at[slot].set(-1),
            out_buf=ms.out_buf,
            n_out=ms.n_out.at[slot].set(0))
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self._admit_ord[slot] = 0
        self._pages_host[slot] = 0
        self._nout_host[slot] = 0
        self._fail_streak[slot] = 0
        return req

    # ---- graceful degradation: evict + requeue under exhaustion ------------

    def _youngest_active(self, exclude=()) -> Optional[int]:
        """The eviction victim: the most recently admitted active slot
        (it loses the least generated work, and greedy decode replays
        its stream identically after re-admission)."""
        slots = [s for s in range(self.max_batch)
                 if self.slot_req[s] is not None and s not in exclude]
        if not slots:
            return None
        return max(slots, key=lambda s: int(self._admit_ord[s]))

    def _drop_slot(self, slot: int) -> Request:
        """Free EVERY page an active slot holds (KV + modality aux)
        back through the allocator and zero its slot state, host and
        device — the shared teardown under eviction (which requeues)
        and cancellation (which drops).  Allocs/frees stay balanced:
        the frees here are counted exactly like retirement frees.
        Returns the slot's request."""
        req = self.slot_req[slot]
        kv = self._kv()
        if self.mega_step:
            # mid-flight the device page-table row is the only page-id
            # holder (slot_pages was cleared at _mega_admit)
            if kv is not None:
                row = np.asarray(kv.page_table[slot])
                self._bulk_free([int(p) for p in row[row >= 0]])
            ms = self.mega_state
            self.mega_state = MegaState(
                last_tok=ms.last_tok.at[slot].set(0),
                lens=ms.lens.at[slot].set(0),
                page_counts=ms.page_counts.at[slot].set(0),
                active=ms.active.at[slot].set(False),
                budget=ms.budget.at[slot].set(0),
                eos=ms.eos.at[slot].set(-1),
                out_buf=ms.out_buf,
                n_out=ms.n_out.at[slot].set(0))
            self._pages_host[slot] = 0
            self._nout_host[slot] = 0
            self._fail_streak[slot] = 0
        else:
            self._bulk_free(self.slot_pages[slot])
            self.slot_pages[slot] = []
        self._free_aux(slot)
        kv = self._kv()
        if kv is not None:
            self._set_kv(kv._replace(
                page_table=kv.page_table.at[slot].set(-1),
                seq_lens=kv.seq_lens.at[slot].set(0)))
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self._admit_ord[slot] = 0
        return req

    def _evict_slot(self, slot: int):
        """Evict one active slot: free every page it holds back
        through the allocator, zero its slot state (host and device),
        and push its request to the FRONT of the waiting queue with
        its generated tokens discarded — re-admission replays the
        identical stream (greedy decode is deterministic), so one
        oversized burst degrades throughput instead of killing the
        server.  Counted in ``stats["evictions"]``."""
        with self.tracer.span("eviction", slot=slot):
            req = self._drop_slot(slot)
        req.out_tokens = []
        req.done = False
        self.waiting.insert(0, req)
        self.stats["evictions"] += 1

    def cancel(self, uid: int) -> bool:
        """Cancel a request — the client-abandonment path (DESIGN.md
        §13).  Three cases, all legal between any two steps:

        - uid still in the **waiting queue**: removed before it ever
          touches a slot;
        - uid **active in a slot**: every page the slot holds (KV +
          modality aux) is freed back through the allocator in bulk —
          allocs and frees stay balanced — and the slot opens for the
          next admission;
        - uid **already retired** (or never submitted): a no-op
          returning ``False``, never a ``KeyError`` — retirement
          legitimately races a client's hangup.

        Returns True iff the request was actually cancelled; counted
        in ``stats["cancels"]``."""
        for i, r in enumerate(self.waiting):
            if r.uid == uid:
                self.waiting.pop(i)
                self.stats["cancels"] += 1
                self.tracer.instant("cancel", uid=uid, where="waiting")
                return True
        for slot in range(self.max_batch):
            r = self.slot_req[slot]
            if r is not None and r.uid == uid:
                with self.tracer.span("cancel", uid=uid, slot=slot):
                    self._drop_slot(slot)
                self.stats["cancels"] += 1
                return True
        return False

    # ---- main loop -----------------------------------------------------------
    def _grow_active(self, active: List[int]) -> List[int]:
        """Decode-step page growth for ALL active slots as ONE bulk
        alloc transaction (previously ``_map_pages`` ran per slot — up
        to ``max_batch`` kernel launches per decode step).  When a
        defragmentation wave fails to reclaim enough pages, evicts the
        youngest slot (freeing its pages, requeueing its request) and
        retries — never raises.  Returns the slots still active."""
        if self._kv() is None:  # attention-free family: O(1) state
            return list(active)
        active = list(active)
        while True:
            slots = []
            for s in active:
                need = -(-(int(self.slot_len[s]) + 1) // self.page)
                slots.extend([s] * (need - len(self.slot_pages[s])))
            if not slots:
                return active
            got = self._alloc_pages([s % self.num_shards for s in slots])
            if all(g >= 0 for g in got):
                self._map_granted(slots, got)
                return active
            self._bulk_free([g for g in got if g >= 0])
            victim = self._youngest_active()
            if victim is None:
                return active
            self._evict_slot(victim)
            if victim in active:
                active.remove(victim)

    def _step_host(self) -> List[Request]:
        """Host-loop decode tick: grow pages (host computes need),
        decode one token for all active slots (token ids — not logits
        — cross the device boundary), retire finished requests."""
        active = [s for s in range(self.max_batch)
                  if self.slot_req[s] is not None]
        finished = []
        if active:
            # growth may evict slots (exhaustion degradation) — decode
            # only the survivors
            active = self._grow_active(active)
        if active:
            toks = np.zeros((self.max_batch, 1), np.int32)
            for s in active:
                toks[s, 0] = self.slot_req[s].out_tokens[-1]
            tok_ids, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.caches)
            nxt = np.asarray(tok_ids)
            for s in active:
                req = self.slot_req[s]
                req.out_tokens.append(int(nxt[s]))
                self.slot_len[s] += 1
                ln = len(req.out_tokens)
                if (ln >= req.max_new_tokens
                        or (req.eos_id is not None
                            and int(nxt[s]) == req.eos_id)):
                    req.done = True
                    finished.append(req)
                    self._release(s)
        return finished

    def step(self) -> List[Request]:
        """Admit, decode one token for all active slots (fused
        mega-step or host loop), retire finished requests.  Returns
        requests finished this step.

        The whole step is one ``tick`` trace span whose category —
        ``"compile"`` when any engine jit traced this step,
        ``"steady"`` otherwise — is resolved at close from the jit
        cache sizes; ``last_tick_compiled`` exposes the same signal to
        the replay harness (DESIGN.md §14)."""
        ts = self.tracer.begin()
        pre = self._compile_count()
        with self.tracer.span("admission"):
            self._admit()
        self._maybe_rebalance()
        finished = (self._step_mega() if self.mega_step
                    else self._step_host())
        self.stats["steps"] += 1
        self._maybe_auto_defrag()
        grew = self._compile_count() - pre
        self.stats["jit_first_calls"] += grew
        self.last_tick_compiled = grew > 0
        self.tracer.complete(
            "tick", ts, cat="compile" if grew > 0 else "steady",
            step=self.stats["steps"], finished=len(finished))
        return finished

    def _release(self, slot: int):
        self._bulk_free(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self._free_aux(slot)
        kv = self._kv()
        if kv is not None:
            pt = kv.page_table.at[slot].set(-1)
            sl = kv.seq_lens.at[slot].set(0)
            self._set_kv(kv._replace(page_table=pt, seq_lens=sl))
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self._admit_ord[slot] = 0

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.waiting and all(r is None for r in self.slot_req):
                break
        return out

    # ---- crash-safe serving: snapshot / restore (DESIGN.md §12) ------------

    def snapshot_fingerprint(self) -> dict:
        """The layout-validation contract (DESIGN.md §12): everything
        that decides how snapshot words are INTERPRETED — the arena
        layout rendering (the same ``describe()`` the golden-layout
        tests pin), allocator geometry, and engine geometry.  A
        snapshot restores only into an engine whose fingerprint
        matches exactly; allocator ``backend``/``lowering`` are
        deliberately absent (transactions are bit-identical across
        them, so a snapshot may restore onto a different one)."""
        kv = self._kv()
        lay = self.ouro.layout
        return {
            "snapshot_version": SNAPSHOT_VERSION,
            "arena_layout": lay.describe(),
            "variant": self.ouro.variant,
            "num_shards": self.num_shards,
            "wpp": self.wpp,
            "page_bytes": self.page_bytes,
            "page_tokens": self.page,
            "num_pages": self.num_pages,
            "arch": self.cfg.name,
            "max_batch": self.max_batch,
            "max_seq": self.max_seq,
            "mega_step": self.mega_step,
            "max_new_cap": (self.max_new_cap if self.mega_step
                            else None),
            "kv_dtype": (None if kv is None
                         else str(kv.layers.k.dtype)),
        }

    def _snapshot_tree(self):
        """The array half of a snapshot (also the restore template):
        arena slabs, KV caches, and — in mega-step mode — the device
        carry plus its host mirrors."""
        tree = {"arena_mem": self.alloc_state.mem,
                "arena_ctl": self.alloc_state.ctl,
                "caches": self.caches,
                "slot_len": np.asarray(self.slot_len)}
        if self.mega_step:
            tree["mega"] = self.mega_state
            tree["pages_host"] = np.asarray(self._pages_host)
            tree["nout_host"] = np.asarray(self._nout_host)
            tree["fail_streak"] = np.asarray(self._fail_streak)
        return tree

    def _snapshot_meta(self) -> dict:
        """The JSON half: fingerprint, request queue, host tables,
        stats counters (everything non-array a restart needs)."""
        meta = {
            "fingerprint": self.snapshot_fingerprint(),
            "uid": self._uid,
            "admit_counter": self._admit_counter,
            "admit_ord": [int(x) for x in self._admit_ord],
            "slot_reqs": [None if r is None else _req_to_json(r)
                          for r in self.slot_req],
            "waiting": [_req_to_json(r) for r in self.waiting],
            "slot_pages": [[int(p) for p in ps]
                           for ps in self.slot_pages],
            "slot_aux": [[int(p) for p in ps]
                         for ps in self.slot_aux],
            "shard_pages": [int(x) for x in self._shard_pages],
            "stats": {k: v for k, v in self.stats.items()},
        }
        # round-trip now: catches an unserializable field at snapshot
        # time (not at some later restore) and deep-copies
        return json.loads(json.dumps(meta))

    def snapshot(self, directory: Optional[str] = None,
                 step: Optional[int] = None, keep: int = 3):
        """Capture the COMPLETE serving state at a step boundary:
        arena word image + control block (all shards), KV page heaps +
        page tables + ``seq_lens``, the mega-step carry and its host
        mirrors, the waiting queue and in-flight requests, and the
        stats block.  With ``directory``, writes an atomic committed
        checkpoint through ckpt/checkpoint.py (requests and the layout
        fingerprint ride the ``meta.json`` sidecar) and returns the
        committed path; otherwise returns the in-memory snapshot dict
        ``{"tree", "meta"}`` that :meth:`restore` accepts directly."""
        with self.tracer.span("snapshot",
                              to_disk=directory is not None):
            meta = self._snapshot_meta()
            if directory is not None:
                from repro.ckpt import checkpoint as CK
                return CK.save(self._snapshot_tree(), directory,
                               step=self.stats["steps"] if step is None
                               else step,
                               keep=keep, extra=meta)
            tree = jax.tree.map(lambda x: np.array(jax.device_get(x)),
                                self._snapshot_tree())
            return {"tree": tree, "meta": meta}

    def restore(self, source, step: Optional[int] = None):
        """Load a snapshot taken by :meth:`snapshot` — an in-memory
        snapshot dict, or a checkpoint directory (newest committed
        step unless ``step`` is given; a step swept by a concurrent
        retention falls back to the next-newest).  The snapshot's
        layout fingerprint is validated FIRST: a snapshot from a
        different ``ArenaLayout`` or engine geometry is rejected
        loudly with a ``ValueError`` naming the differing fields —
        never silently misinterpreted.  After restore, decoding
        resumes token-identically for every in-flight sequence.
        Returns the restored checkpoint step (None for in-memory
        snapshots)."""
        with self.tracer.span("restore"):
            if isinstance(source, str):
                from repro.ckpt import checkpoint as CK
                meta_rec, s = CK.read_meta(source, step)
                meta = meta_rec.get("extra")
                if meta is None or "fingerprint" not in meta:
                    raise ValueError(
                        f"checkpoint step {s} under {source!r} is not "
                        f"a serving-engine snapshot (no fingerprint "
                        f"sidecar)")
                self._validate_fingerprint(meta["fingerprint"])
                tree, s = CK.restore(self._snapshot_tree(), source,
                                     step=s)
                self._apply_snapshot(tree, meta)
                return s
            meta = source["meta"]
            self._validate_fingerprint(meta["fingerprint"])
            self._apply_snapshot(source["tree"], meta)
            return None

    def _validate_fingerprint(self, fp: dict):
        mine = self.snapshot_fingerprint()
        if fp != mine:
            diffs = sorted(k for k in set(fp) | set(mine)
                           if fp.get(k) != mine.get(k))
            raise ValueError(
                f"snapshot layout fingerprint mismatch on fields "
                f"{diffs} — refusing to restore: a snapshot from a "
                f"different ArenaLayout or engine geometry would be "
                f"silently misinterpreted (snapshot "
                f"{ {k: fp.get(k) for k in diffs} !r} vs engine "
                f"{ {k: mine.get(k) for k in diffs} !r})")

    def _apply_snapshot(self, tree, meta):
        """Install validated snapshot state (fingerprint already
        checked; every array leaf is additionally shape/dtype-checked
        against the live engine before anything is mutated)."""
        def check(path, new, old):
            new = jnp.asarray(np.asarray(new))
            old = jnp.asarray(old)
            if new.shape != old.shape or new.dtype != old.dtype:
                raise ValueError(
                    f"snapshot leaf {jax.tree_util.keystr(path)}: "
                    f"shape/dtype {new.shape}/{new.dtype} does not "
                    f"match the engine's {old.shape}/{old.dtype}")
            return new

        mapped = jax.tree_util.tree_map_with_path(
            check, tree, self._snapshot_tree())
        self.alloc_state = self.alloc_state._replace(
            mem=mapped["arena_mem"], ctl=mapped["arena_ctl"])
        self.caches = mapped["caches"]
        self.slot_len = np.asarray(mapped["slot_len"], np.int64).copy()
        if self.mega_step:
            self.mega_state = mapped["mega"]
            self._pages_host = np.asarray(mapped["pages_host"],
                                          np.int64).copy()
            self._nout_host = np.asarray(mapped["nout_host"],
                                         np.int64).copy()
            self._fail_streak = np.asarray(mapped["fail_streak"],
                                           np.int64).copy()
        self.slot_req = [None if d is None else _req_from_json(d)
                         for d in meta["slot_reqs"]]
        self.waiting = [_req_from_json(d) for d in meta["waiting"]]
        self.slot_pages = [[int(p) for p in ps]
                           for ps in meta["slot_pages"]]
        self.slot_aux = [[int(p) for p in ps]
                         for ps in meta.get(
                             "slot_aux", [[]] * self.max_batch)]
        self._uid = int(meta["uid"])
        self._admit_counter = int(meta["admit_counter"])
        self._admit_ord = np.asarray(meta["admit_ord"], np.int64)
        self._shard_pages = np.asarray(meta["shard_pages"], np.int64)
        # counters restore; engine-identity fields (which backend /
        # lowering / launch count THIS process runs) stay fresh
        identity = {"arena_mem_words", "arena_ctl_words",
                    "alloc_backend", "alloc_lowering", "num_shards",
                    "mega_step", "launches_per_tick",
                    "aux_pages_per_slot", "jit_first_calls"}
        for k, v in meta["stats"].items():
            if k in self.stats and k not in identity:
                self.stats[k] = v
        self.refresh_frag_stats()


def _tokens_of(model_out):
    """(logits, caches) → (greedy token ids, caches): the argmax runs
    inside the jit so only (B,) int32 ids are ever fetched."""
    logits, caches = model_out
    return jnp.argmax(logits, -1).astype(jnp.int32), caches
