"""Traffic-replay harness: realistic load across the config zoo.

The paper's claim is ONE dynamic allocator for *heterogeneous*
workloads; the serving engine, however, grew up on a single dense-LM
path.  This module is the jax_pallas analogue of the driver-style
stress harnesses GPU memory-manager work validates with: a
deterministic, seedable traffic generator (Poisson arrivals, bursty
spikes, mixed prompt/output length distributions, client abandonment
mid-stream) plus a replay driver that pushes any :class:`ServingEngine`
through a trace while recording the latency/fragmentation trajectory
(p50/p99 tick latency, queue wait, evictions, ``frag_ratio``,
defrag-wave counts).

Determinism is the contract everything else leans on:
``generate_trace(scenario, seed=s, ...)`` is a pure function of its
arguments, and a trace replays **identically** (token-for-token per
uid) on the host decode loop and the fused mega-step, on any allocator
backend/lowering, and at any shard count — so the harness doubles as
the engine's hardest correctness test (:func:`replay_pair` +
:func:`assert_conserved`).  Abandonment is expressed in absolute
engine-step time (cancel at step ``t``), which both decode modes reach
through the identical host-side admission machinery, keeping cancels
parity-safe.

Per-modality page policy rides underneath (DESIGN.md §13): SSM state
pages (mamba2 / recurrentgemma) and MoE expert buffers (mixtral /
phi3.5) are granted out of the SAME Ouroboros arena as KV pages
(``kv_cache.modality_page_quota``), so every family's traffic churns
the allocator — not just the attention archs.

    from repro.serve.replay import SCENARIOS, engine_factory, \
        generate_trace, replay, replay_pair
    cfg, make = engine_factory("mamba2-780m")
    trace = generate_trace(SCENARIOS["bursty"], seed=0,
                           vocab_size=cfg.vocab_size)
    host, mega = replay_pair(make(mega=False), make(mega=True), trace)
    print(host.summary())
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One deterministic traffic shape.

    All randomness flows from the seed handed to
    :func:`generate_trace`; two calls with identical ``(scenario,
    seed, vocab_size, ...)`` yield identical traces.  Lengths are
    mixtures: a prompt is drawn from ``prompt_long`` with probability
    ``long_frac``, else from ``prompt_short`` (both inclusive uniform
    ranges); output budgets come from ``out_lens``.  A client
    abandons with probability ``abandon_frac``, hanging up
    ``abandon_after``-many steps after arrival (absolute engine-step
    time — parity-safe across decode modes)."""
    name: str
    n_requests: int = 12
    arrival: str = "poisson"            # poisson | burst
    rate: float = 0.75                  # poisson: mean arrivals / step
    burst_every: int = 10               # burst: steps between spikes
    burst_size: int = 5                 # burst: arrivals per spike
    prompt_short: Tuple[int, int] = (4, 12)
    prompt_long: Tuple[int, int] = (20, 44)
    long_frac: float = 0.25
    out_lens: Tuple[int, int] = (2, 14)
    abandon_frac: float = 0.0
    abandon_after: Tuple[int, int] = (2, 12)

    def __post_init__(self):
        if self.arrival not in ("poisson", "burst"):
            raise ValueError(
                f"unknown arrival process {self.arrival!r}; pick from "
                f"('poisson', 'burst')")
        if not 0.0 <= self.abandon_frac <= 1.0:
            raise ValueError(
                f"abandon_frac must be in [0, 1], got "
                f"{self.abandon_frac!r}")


#: The scenario zoo every config family replays (benchmarks/
#: fig9_replay.py, tests/test_replay.py).  ``steady`` is the paper-
#: regime baseline; ``bursty`` spikes admissions past ``max_batch`` so
#: the queue and allocator churn together; ``abandon`` kills half the
#: clients mid-stream, exercising ``ServingEngine.cancel`` and the
#: conservation contract under partial lifecycles.
SCENARIOS = {
    "steady": Scenario("steady"),
    "bursty": Scenario("bursty", arrival="burst", burst_every=8,
                       burst_size=6, n_requests=18, long_frac=0.4),
    "abandon": Scenario("abandon", abandon_frac=0.5, n_requests=14,
                        out_lens=(6, 14)),
}


@dataclasses.dataclass(frozen=True)
class TraceItem:
    """One client in a trace: arrives at ``step``, submits ``prompt``
    with budget ``max_new``, and — if abandoning — cancels at absolute
    step ``cancel_step`` (None = stays to completion)."""
    step: int
    prompt: Tuple[int, ...]
    max_new: int
    cancel_step: Optional[int]


def generate_trace(scenario: Scenario, *, seed: int, vocab_size: int,
                   max_seq: int = 96, max_new_cap: int = 32
                   ) -> List[TraceItem]:
    """Deterministic trace for ``scenario``: a list of
    :class:`TraceItem` sorted by arrival step.  Prompt + budget are
    clipped so every request fits ``max_seq`` (the harness stresses
    the allocator via churn and concurrency, not via over-long
    sequences) and budgets respect the engine's mega-step
    ``max_new_cap``.

    >>> from repro.serve.replay import SCENARIOS, generate_trace
    >>> a = generate_trace(SCENARIOS["steady"], seed=7, vocab_size=64)
    >>> b = generate_trace(SCENARIOS["steady"], seed=7, vocab_size=64)
    >>> a == b                      # same seed, identical trace
    True
    >>> c = generate_trace(SCENARIOS["steady"], seed=8, vocab_size=64)
    >>> a != c                      # seeds actually steer the stream
    True
    """
    rng = np.random.default_rng(seed)
    sc = scenario
    # ---- arrival steps ----------------------------------------------------
    steps: List[int] = []
    t = 0
    while len(steps) < sc.n_requests:
        if sc.arrival == "poisson":
            k = int(rng.poisson(sc.rate))
        else:  # burst: a spike every burst_every steps, quiet between
            k = sc.burst_size if t % sc.burst_every == 0 else 0
        steps.extend([t] * min(k, sc.n_requests - len(steps)))
        t += 1
    # ---- lengths, budgets, abandonment ------------------------------------
    items = []
    for step in steps:
        lo, hi = (sc.prompt_long if rng.random() < sc.long_frac
                  else sc.prompt_short)
        budget = int(rng.integers(sc.out_lens[0], sc.out_lens[1] + 1))
        budget = min(budget, max_new_cap)
        lp = int(rng.integers(lo, hi + 1))
        lp = max(1, min(lp, max_seq - budget - 2))
        prompt = tuple(int(x) for x in
                       rng.integers(2, vocab_size, lp))
        cancel = None
        if rng.random() < sc.abandon_frac:
            cancel = step + int(rng.integers(sc.abandon_after[0],
                                             sc.abandon_after[1] + 1))
        items.append(TraceItem(step, prompt, budget, cancel))
    return items


@dataclasses.dataclass
class ReplayResult:
    """What one replay of one trace through one engine produced."""
    scenario: str
    arch: str
    mode: str                       # host | mega
    tokens: Dict[int, List[int]]    # uid → emitted tokens (completed)
    cancelled: List[int]            # uids actually cancelled
    steps: int
    tick_ms: List[float]            # wall-clock per engine step
    queue_wait: Dict[int, int]      # uid → steps arrival → admission
    stats: dict                     # engine stats at drain
    compiled: List[bool] = dataclasses.field(default_factory=list)
    # ^ per tick: did this step pay a jit first-call?  (engine
    #   last_tick_compiled — DESIGN.md §14)

    def summary(self) -> dict:
        """The per-scenario telemetry cell appended (as ``replay``
        records) to BENCH_serve.json — p50/p99 tick latency and queue
        wait, completion/abandonment counts, and the allocator's
        fragmentation/defrag trajectory.

        Compile pollution is split out, not blended in: ticks that
        paid a jit first-call (trace+compile — seconds on a
        microsecond-scale loop) are summed into ``compile_ms`` and
        excluded from the ``*_steady`` percentiles.  The unsplit
        ``tick_ms_p50``/``p99`` keep their historical all-ticks
        meaning, so pre-split BENCH_serve records remain comparable."""
        s = self.stats
        waits = list(self.queue_wait.values()) or [0]
        frag = s["frag_ratio"]
        frag = max(frag) if isinstance(frag, list) else frag
        flags = (self.compiled if len(self.compiled) == len(self.tick_ms)
                 else [False] * len(self.tick_ms))
        steady = [ms for ms, c in zip(self.tick_ms, flags) if not c]
        steady = steady or list(self.tick_ms)   # all-compile fallback
        return {
            "scenario": self.scenario,
            "arch": self.arch,
            "mode": self.mode,
            "requests": len(self.tokens) + len(self.cancelled),
            "completed": len(self.tokens),
            "cancelled": len(self.cancelled),
            "steps": self.steps,
            "tokens": sum(len(t) for t in self.tokens.values()),
            "tick_ms_p50": float(np.percentile(self.tick_ms, 50)),
            "tick_ms_p99": float(np.percentile(self.tick_ms, 99)),
            "compile_ms": float(sum(
                ms for ms, c in zip(self.tick_ms, flags) if c)),
            "tick_ms_p50_steady": float(np.percentile(steady, 50)),
            "tick_ms_p99_steady": float(np.percentile(steady, 99)),
            "queue_wait_p50": float(np.percentile(waits, 50)),
            "queue_wait_p99": float(np.percentile(waits, 99)),
            "evictions": s["evictions"],
            "defrag_waves": s["defrag_waves"],
            "auto_defrag_waves": s["auto_defrag_waves"],
            "pages_migrated": s["pages_migrated"],
            "aux_pages_per_slot": s["aux_pages_per_slot"],
            "allocs": s["allocs"],
            "frees": s["frees"],
            "frag_ratio_final": float(frag),
        }


def replay(engine, trace: List[TraceItem], *, scenario: str = "",
           max_steps: int = 2000) -> ReplayResult:
    """Drive ``engine`` through ``trace`` to drain: submit arrivals at
    their step, issue scheduled cancels (:meth:`ServingEngine.cancel`),
    tick the engine once per step, and record completion tokens, tick
    latency, and queue waits.  Raises if the trace fails to drain
    within ``max_steps`` — a hung replay is a bug, not a timeout."""
    items = sorted(trace, key=lambda it: it.step)
    uid_of: Dict[int, int] = {}         # trace index → engine uid
    cancel_at: Dict[int, List[int]] = {}
    arrived: Dict[int, int] = {}        # uid → arrival step
    admitted: Dict[int, int] = {}       # uid → admission step
    tokens: Dict[int, List[int]] = {}
    cancelled: List[int] = []
    tick_ms: List[float] = []
    compiled: List[bool] = []
    next_i = 0
    t = 0
    while t < max_steps:
        while next_i < len(items) and items[next_i].step <= t:
            it = items[next_i]
            uid = engine.submit(np.asarray(it.prompt, np.int32),
                                max_new_tokens=it.max_new)
            uid_of[next_i] = uid
            arrived[uid] = t
            if it.cancel_step is not None:
                cancel_at.setdefault(max(it.cancel_step, t + 1),
                                     []).append(uid)
            next_i += 1
        for uid in cancel_at.pop(t, []):
            if uid not in tokens and engine.cancel(uid):
                cancelled.append(uid)
        t0 = time.perf_counter()
        done = engine.step()
        tick_ms.append(1e3 * (time.perf_counter() - t0))
        compiled.append(bool(getattr(engine, "last_tick_compiled",
                                     False)))
        for slot in range(engine.max_batch):
            r = engine.slot_req[slot]
            if r is not None and r.uid not in admitted:
                admitted[r.uid] = t
        for r in done:
            tokens[r.uid] = list(r.out_tokens)
            admitted.setdefault(r.uid, t)
        t += 1
        if (next_i == len(items) and not engine.waiting
                and all(r is None for r in engine.slot_req)):
            break
    else:
        raise RuntimeError(
            f"replay did not drain within {max_steps} steps "
            f"({len(tokens)} completed, {len(cancelled)} cancelled of "
            f"{len(items)})")
    engine.refresh_frag_stats()
    return ReplayResult(
        scenario=scenario,
        arch=engine.cfg.name,
        mode="mega" if engine.mega_step else "host",
        tokens=tokens,
        cancelled=sorted(cancelled),
        steps=t,
        tick_ms=tick_ms,
        queue_wait={u: admitted[u] - arrived[u] for u in admitted},
        stats=dict(engine.stats),
        compiled=compiled)


def assert_conserved(engine):
    """End-state allocator conservation after a drained replay: every
    page ever granted — KV, SSM-state, MoE-buffer alike — went back
    through the allocator (``allocs == frees``), no slot holds page
    ids, and the device page table is all holes.  Abandonment and
    eviction paths free through the same counters, so a leak anywhere
    in the lifecycle trips this."""
    s = engine.stats
    assert s["allocs"] == s["frees"], (
        f"page leak: {s['allocs']} allocs vs {s['frees']} frees "
        f"({s['allocs'] - s['frees']} pages stranded)")
    assert all(not p for p in engine.slot_pages), engine.slot_pages
    assert all(not p for p in engine.slot_aux), engine.slot_aux
    kv = engine._kv()
    if kv is not None:
        pt = np.asarray(kv.page_table)
        assert (pt < 0).all(), f"page table still maps {int((pt >= 0).sum())} pages"
    if engine.mega_step:
        engine._sync_shard_pages_from_table()
    assert sum(engine.stats["shard_pages_live"]) == 0, (
        engine.stats["shard_pages_live"])


def replay_pair(engine_a, engine_b, trace, *, scenario: str = "",
                max_steps: int = 2000):
    """The parity harness: replay the SAME trace through two engine
    configurations (canonically host loop vs fused mega-step, or
    shards 1 vs 4) and assert token-for-token agreement per uid, the
    same cancelled-uid set, and end-state conservation on both.
    Returns the two :class:`ReplayResult`."""
    ra = replay(engine_a, trace, scenario=scenario, max_steps=max_steps)
    rb = replay(engine_b, trace, scenario=scenario, max_steps=max_steps)
    assert ra.cancelled == rb.cancelled, (
        f"cancelled sets diverge: {ra.mode}={ra.cancelled} vs "
        f"{rb.mode}={rb.cancelled}")
    assert set(ra.tokens) == set(rb.tokens), (
        f"completed sets diverge: {sorted(ra.tokens)} vs "
        f"{sorted(rb.tokens)}")
    for uid in ra.tokens:
        assert ra.tokens[uid] == rb.tokens[uid], (
            f"uid {uid} token streams diverge between {ra.mode} and "
            f"{rb.mode}: {ra.tokens[uid]} vs {rb.tokens[uid]}")
    assert_conserved(engine_a)
    assert_conserved(engine_b)
    return ra, rb


def engine_factory(arch: str, *, max_batch: int = 3, max_seq: int = 96,
                   max_new_cap: int = 32, seed: int = 0):
    """Build the reduced (smoke) config + params for ``arch`` ONCE and
    return ``(cfg, make)`` where ``make(mega=..., **engine_kw)``
    constructs a fresh float32 :class:`ServingEngine` over the shared
    params — the cheap way to stand up host/mega (or shard-count)
    pairs for parity replays."""
    from repro.configs import get_arch
    from repro.models.model import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_arch(arch).smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(seed))

    def make(mega: bool = False, **kw):
        kw.setdefault("max_batch", max_batch)
        kw.setdefault("max_seq", max_seq)
        kw.setdefault("max_new_cap", max_new_cap)
        return ServingEngine(m, params, kv_dtype=jnp.float32,
                             compute_dtype=jnp.float32,
                             mega_step=mega, **kw)

    return cfg, make
