"""Mamba2-780M — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060; unverified].  d_inner = 2×1536 = 3072, 48 SSD heads
of dim 64, state N=128.  Runs long_500k (O(1) decode state)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,      # attention-free; SSD heads derived from d_inner
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv=4,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
))
