"""SeamlessM4T-large-v2 — speech/text encoder-decoder [arXiv:2308.11596; hf].

Enc-dec transformer backbone; the w2v-BERT speech frontend is a STUB per
the assignment (``input_specs()`` provides precomputed frame embeddings
for the encoder).  The assigned 24L is instantiated as 24 encoder + 24
decoder layers (the published text-to-text stack is 24+24).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,       # decoder
    enc_layers=24,       # encoder
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    rope_theta=1e4,
    modality="audio",
    source="[arXiv:2308.11596; hf]",
))
