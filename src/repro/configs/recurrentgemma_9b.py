"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 1 attention
block per 3 [arXiv:2402.19427; unverified].  Sub-quadratic: runs
long_500k (RG-LRU state is O(1); local attention window-bounded)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    rope_theta=1e4,
    lru_width=4096,
    attn_period=3,
    local_window=2048,
    source="[arXiv:2402.19427; unverified]",
))
