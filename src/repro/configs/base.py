"""Model/arch configuration system.

One flat frozen dataclass covers every assigned family (dense / moe /
ssm / hybrid / enc-dec / vlm / audio); per-arch files instantiate it
with the exact published numbers and register under their ``--arch`` id.

``smoke()`` returns the reduced same-family config every architecture's
CPU smoke test runs (few layers, narrow width, tiny vocab); the FULL
config is exercised only through the dry-run (ShapeDtypeStruct only).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ARCH_REGISTRY = {}


def register(cfg: "ModelConfig") -> "ModelConfig":
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> "ModelConfig":
    # populate the registry on first use
    from repro import configs  # noqa: F401  (imports all arch modules)
    if name not in ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, ...]] = None  # qwen2-vl M-RoPE
    sliding_window: Optional[int] = None    # mixtral SWA
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    act: str = "silu"                       # silu (SwiGLU) | gelu (GeGLU)
    parallel_block: bool = False            # command-r parallel attn+ffn
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (recurrentgemma: every `attn_period`-th block is local attn)
    lru_width: Optional[int] = None
    attn_period: int = 3
    local_window: int = 2048

    # encoder-decoder
    enc_layers: int = 0

    # modality frontend stub: None | "vision" | "audio"
    modality: Optional[str] = None

    # source annotation [source; verified-tier]
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §Arch-applicability)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16 sharding divides."""
        return -(-self.vocab_size // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim_
        per_attn = (self.num_heads * hd * d
                    + 2 * self.num_kv_heads * hd * d
                    + self.num_heads * hd * d)
        per_mlp = 3 * d * f
        n = 0
        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            per = (d * (2 * di + 2 * self.ssm_ngroups * ns + self.ssm_nheads)
                   + di * d)
            n += self.num_layers * per
        elif self.family == "hybrid":
            lw = self.lru_width or d
            n_attn = self.num_layers // self.attn_period
            n_rec = self.num_layers - n_attn
            per_rec = d * lw * 2 + lw * d + 2 * lw  # in/out proj + gates
            n += n_attn * per_attn + n_rec * per_rec + self.num_layers * per_mlp
        else:
            layers = self.num_layers + self.enc_layers
            n += layers * per_attn
            if self.num_experts:
                n += self.num_layers * (self.num_experts * per_mlp
                                        + d * self.num_experts)
            else:
                n += layers * per_mlp
            if self.is_encdec:
                n += self.num_layers * per_attn  # cross attention
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        moe = self.num_layers * self.num_experts * 3 * self.d_model * self.d_ff
        active = self.num_layers * self.num_experts_per_tok * 3 \
            * self.d_model * self.d_ff
        return full - moe + active

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, self.attn_period + 1
                           if self.family == "hybrid" else 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=256,
            head_dim=32,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            ssm_state=min(self.ssm_state, 32),
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=16,
            lru_width=128 if self.lru_width else None,
            local_window=64 if self.family == "hybrid" else self.local_window,
            sliding_window=64 if self.sliding_window else None,
            enc_layers=min(self.enc_layers, 2),
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
        )


# ---- input shape sets (assigned; seq_len × global_batch) -------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ModelConfig):
    """The (arch × shape) cells this arch runs (skips per DESIGN.md)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]
