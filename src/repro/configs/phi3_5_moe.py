"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].  16 experts shard exactly over
the model=16 mesh axis (expert parallelism)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    rope_theta=1e4,
    num_experts=16,
    num_experts_per_tok=2,
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
))
