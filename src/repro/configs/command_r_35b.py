"""Command-R 35B — dense LM, parallel attn+FFN block, layernorm, no bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8e6,
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
))
