"""Mixtral-8x7B — sparse MoE (8 experts, top-2) with sliding-window
attention [arXiv:2401.04088; hf].  SWA makes it long_500k-eligible."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e6,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    source="[arXiv:2401.04088; hf]",
))
