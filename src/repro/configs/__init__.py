"""Arch registry: importing this package registers all assigned archs."""
from repro.configs.base import (ARCH_REGISTRY, SHAPES, ModelConfig,
                                ShapeConfig, cells_for, get_arch)
from repro.configs import (  # noqa: F401
    command_r_35b, internlm2_20b, mamba2_780m, mixtral_8x7b, phi3_5_moe,
    qwen1_5_32b, qwen2_0_5b, qwen2_vl_2b, recurrentgemma_9b,
    seamless_m4t_large_v2)

ALL_ARCHS = tuple(sorted(ARCH_REGISTRY))

__all__ = ["ARCH_REGISTRY", "ALL_ARCHS", "SHAPES", "ModelConfig",
           "ShapeConfig", "cells_for", "get_arch"]
