"""Qwen2-VL-2B — vision-language backbone [arXiv:2409.12191; hf].

M-RoPE (temporal/height/width sections) and dynamic-resolution vision;
the vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings merged into the token stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
    modality="vision",
    source="[arXiv:2409.12191; hf]",
))
