"""Production mesh construction.

Functions (not module-level constants) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (v5e pod); 2 pods over DCN when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has (tests / examples): 1D data."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
