"""End-to-end serving driver: continuous batching over the Ouroboros
paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --smoke --requests 12 --max-new 16

Crash-safe mode (DESIGN.md §12): with ``--snapshot-dir`` a
``PreemptionGuard`` arms SIGTERM/SIGINT — the loop finishes the
in-flight tick, snapshots the complete serving state (arena + KV pages
+ queue) and exits with code 3; a restart with ``--resume`` picks the
stream back up token-identically.  Each completed request prints a
stable ``REQ <uid> <tokens...>`` line, so killed-run + resumed-run
output concatenates to exactly the uninterrupted run's output (the CI
crash-restart smoke asserts this).

Observability (DESIGN.md §14): ``--metrics-file`` periodically exports
the metrics registry (engine stats, frag gauges, drained in-kernel
allocator telemetry) as Prometheus text or JSON; ``--trace-file``
emits a Chrome/Perfetto trace of engine phase spans with compile ticks
tagged distinctly from steady-state ticks.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alloc-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="allocator transaction backend (fused Pallas "
                         "kernels or jnp reference path)")
    ap.add_argument("--alloc-lowering",
                    choices=("auto", "whole", "blocked"), default="auto",
                    help="Pallas kernel lowering (whole-arena refs vs "
                         "region-blocked; DESIGN.md §8) — the active "
                         "one is reported in the engine stats")
    ap.add_argument("--num-shards", type=int, default=1,
                    help="shard the KV page allocator into N "
                         "independent arenas with overflow routing "
                         "(core/shards.py, DESIGN.md §9); per-shard "
                         "occupancy lands in the engine stats")
    ap.add_argument("--mega", action="store_true",
                    help="fused decode mega-step: grow + forward + "
                         "sample as ONE jitted tick with device-"
                         "resident slot state (DESIGN.md §11); "
                         "launches_per_tick lands in the engine stats")
    ap.add_argument("--defrag-threshold", type=float, default=None,
                    metavar="RATIO",
                    help="fire a proactive defrag wave when frag_ratio "
                         "exceeds RATIO (0-1; default: only the "
                         "allocation-failure retry defrags)")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="arm crash-safe serving: SIGTERM/SIGINT "
                         "finishes the current tick, snapshots the "
                         "complete serving state into DIR "
                         "(ckpt/checkpoint.py atomic layout) and "
                         "exits with code 3")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest committed snapshot under "
                         "--snapshot-dir and resume mid-stream "
                         "(token-identically) instead of submitting "
                         "fresh requests")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="write the metrics registry (engine stats, "
                         "frag gauges, drained in-kernel telemetry) to "
                         "PATH as Prometheus text exposition "
                         "(.json suffix → JSON) every --metrics-every "
                         "steps and at drain (obs/metrics.py, "
                         "DESIGN.md §14)")
    ap.add_argument("--metrics-every", type=int, default=50,
                    metavar="STEPS",
                    help="steps between --metrics-file rewrites "
                         "(default 50)")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="emit a Chrome/Perfetto trace_event JSON of "
                         "engine phase spans to PATH at exit — compile "
                         "ticks tagged distinctly from steady ticks "
                         "(obs/trace.py, DESIGN.md §14)")
    args = ap.parse_args(argv)
    if args.metrics_every < 1:
        ap.error("--metrics-every must be >= 1")
    if args.resume and not args.snapshot_dir:
        ap.error("--resume requires --snapshot-dir")

    import jax
    import numpy as np
    from repro.configs import get_arch
    from repro.models.model import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    tracer = None
    if args.trace_file:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    eng = ServingEngine(model, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        alloc_backend=args.alloc_backend,
                        alloc_lowering=args.alloc_lowering,
                        num_shards=args.num_shards,
                        mega_step=args.mega,
                        max_new_cap=max(args.max_new, 16),
                        defrag_threshold=args.defrag_threshold,
                        tracer=tracer)
    if args.mega:
        eng.launches_per_tick()  # record into stats before serving

    def write_metrics():
        if not args.metrics_file:
            return
        eng.publish_metrics().write(
            args.metrics_file,
            fmt="json" if args.metrics_file.endswith(".json")
            else "prometheus")

    guard = None
    if args.snapshot_dir:
        from repro.ft.runtime import PreemptionGuard
        guard = PreemptionGuard()

    if args.resume:
        step = eng.restore(args.snapshot_dir)
        print(f"resumed from snapshot step {step} "
              f"under {args.snapshot_dir}", flush=True)
    else:
        rng = np.random.default_rng(args.seed)
        for _ in range(args.requests):
            plen = int(rng.integers(4, args.max_seq // 4))
            eng.submit(rng.integers(2, cfg.vocab_size, plen),
                       max_new_tokens=args.max_new)

    t0 = time.time()
    done, preempted = [], False
    for tick in range(100000):
        finished = eng.step()
        if args.metrics_file and tick % args.metrics_every == 0:
            write_metrics()
        for r in finished:
            # one stable line per completed stream: killed-run output +
            # resumed-run output must concatenate to the uninterrupted
            # run's output (the crash-restart smoke diffs these)
            print("REQ", r.uid, *r.out_tokens, flush=True)
        done.extend(finished)
        drained = (not eng.waiting
                   and all(s is None for s in eng.slot_req))
        if drained:
            break
        if guard is not None and guard.should_stop:
            path = eng.snapshot(directory=args.snapshot_dir)
            print(f"preempted: snapshot committed to {path}",
                  flush=True)
            preempted = True
            break
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    write_metrics()
    if tracer is not None:
        tracer.write(args.trace_file)
        print(f"trace written to {args.trace_file} "
              f"({len(tracer.events)} events)", flush=True)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s incl. compile)")
    print(f"allocator stats: {eng.stats}")
    if preempted:
        return 3
    return 0 if drained else 1


if __name__ == "__main__":
    sys.exit(main())
