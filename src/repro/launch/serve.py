"""End-to-end serving driver: continuous batching over the Ouroboros
paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --smoke --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alloc-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="allocator transaction backend (fused Pallas "
                         "kernels or jnp reference path)")
    ap.add_argument("--alloc-lowering",
                    choices=("auto", "whole", "blocked"), default="auto",
                    help="Pallas kernel lowering (whole-arena refs vs "
                         "region-blocked; DESIGN.md §8) — the active "
                         "one is reported in the engine stats")
    ap.add_argument("--num-shards", type=int, default=1,
                    help="shard the KV page allocator into N "
                         "independent arenas with overflow routing "
                         "(core/shards.py, DESIGN.md §9); per-shard "
                         "occupancy lands in the engine stats")
    ap.add_argument("--mega", action="store_true",
                    help="fused decode mega-step: grow + forward + "
                         "sample as ONE jitted tick with device-"
                         "resident slot state (DESIGN.md §11); "
                         "launches_per_tick lands in the engine stats")
    ap.add_argument("--defrag-threshold", type=float, default=None,
                    metavar="RATIO",
                    help="fire a proactive defrag wave when frag_ratio "
                         "exceeds RATIO (0-1; default: only the "
                         "allocation-failure retry defrags)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_arch
    from repro.models.model import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(model, params, max_batch=args.max_batch,
                        max_seq=args.max_seq,
                        alloc_backend=args.alloc_backend,
                        alloc_lowering=args.alloc_lowering,
                        num_shards=args.num_shards,
                        mega_step=args.mega,
                        max_new_cap=max(args.max_new, 16),
                        defrag_threshold=args.defrag_threshold)
    if args.mega:
        eng.launches_per_tick()  # record into stats before serving

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        eng.submit(rng.integers(2, cfg.vocab_size, plen),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    done = eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(f"allocator stats: {eng.stats}")
    return 0 if len(done) == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
