import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/init: jax locks device count on first use.

__doc__ = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train_step /
prefill_step / serve_step), lowers it with ShapeDtypeStruct inputs
(zero allocation), compiles for the production mesh, and records:

    memory_analysis   — proves the cell fits per-chip HBM
    cost_analysis     — HLO FLOPs / bytes for the roofline terms
    collective bytes  — parsed from the partitioned HLO

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, which
benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--all] [--out experiments/dryrun]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells_for, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model, kv_dtype_for
from repro.models import transformer as TF
from repro.paged import kv_cache as KVC
from repro.parallel.sharding import ShardingRules, use_rules
from repro.train.optimizer import AdamW
from repro.train.train_step import (abstract_state, make_train_step,
                                    state_logical_axes)

# ---------------------------------------------------------------------------
# HLO collective parsing (§Roofline: collective bytes are NOT in
# cost_analysis — sum operand sizes of every collective op)
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"%?([\w.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def hlo_collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (operand sizes)."""
    shapes = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))
    out = {k: 0 for k in _COLL}
    counts = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        for kind in _COLL:
            if re.search(rf"\b{kind}(-start|-done)?\(", line):
                if f"{kind}-done" in line:
                    break  # counted at -start
                args = re.findall(r"\(([^)]*)\)", line)
                total = 0
                if args:
                    for a in args[0].split(","):
                        a = a.strip().lstrip("%")
                        a = a.split(" ")[0]
                        total += shapes.get(a, 0)
                out[kind] += total
                counts[kind] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts,
            "total": sum(out[k] for k in _COLL)}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def shaped_batch(cfg: ModelConfig, shape: ShapeConfig):
    return input_specs(cfg, shape)


def batch_shardings(rules: ShardingRules, batch):
    def spec(path_unused, x):
        if x.ndim >= 2:
            return NamedSharding(rules.mesh,
                                 rules.spec_for(("batch", "seq"), x.shape))
        return NamedSharding(rules.mesh, rules.spec_for(("batch",), x.shape))
    return jax.tree_util.tree_map_with_path(spec, batch)


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig,
                  kv_dtype=None, window_ring: bool = False):
    """Abstract caches + one-token batch for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    m = build_model(cfg)
    kv_dtype = kv_dtype or kv_dtype_for(cfg, s, b)
    caches = m.make_decode_caches(b, max_seq=s, kv_dtype=kv_dtype,
                                  abstract=True, window_ring=window_ring)
    if cfg.is_encdec:
        # decode consumes prefill-built cross-attention KV (source side)
        sds = jax.ShapeDtypeStruct
        hd = cfg.head_dim_
        caches = caches._replace(
            cross_k=sds((cfg.num_layers, b, s, cfg.num_kv_heads, hd),
                        jnp.bfloat16),
            cross_v=sds((cfg.num_layers, b, s, cfg.num_kv_heads, hd),
                        jnp.bfloat16),
            enc_valid=sds((b,), jnp.int32))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return tokens, caches


def cache_shardings(rules: ShardingRules, caches):
    """KV page heaps fully sharded (pages over every axis); page tables
    and scalar state replicated; recurrent states: batch × model;
    enc-dec cross-KV: batch over DP, source length over model."""
    mesh = rules.mesh

    def one(x):
        if x is None:
            return None
        if x.ndim == 5:     # (L, NP, page, Hkv, hd) page heap
            return NamedSharding(mesh, rules.spec_for(
                (None, "pages", None, None, None), x.shape))
        if x.ndim == 4:     # kv scales (L, NP, page, Hkv)
            return NamedSharding(mesh, rules.spec_for(
                (None, "pages", None, None), x.shape))
        if x.ndim == 3:     # ssm conv (Lr, B, ...) / rglru states
            return NamedSharding(mesh, rules.spec_for(
                (None, "batch", "mlp"), x.shape))
        if x.ndim == 2:     # page_table (B, P)
            return NamedSharding(mesh, rules.spec_for(
                ("batch", None), x.shape))
        return NamedSharding(mesh, P())

    def ssm5(x):  # (Lr, B, H, P, N)
        return NamedSharding(mesh, rules.spec_for(
            (None, "batch", "heads", None, None), x.shape))

    out = jax.tree.map(one, caches)
    if getattr(caches, "ssm_h", None) is not None:
        if caches.ssm_h.ndim == 5:
            out = out._replace(ssm_h=ssm5(caches.ssm_h))
    if getattr(caches, "cross_k", None) is not None:
        xsh = NamedSharding(mesh, rules.spec_for(
            (None, "batch", "seq", None, None), caches.cross_k.shape))
        out = out._replace(cross_k=xsh, cross_v=xsh)
    return out


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               remat_policy: str = "full", microbatches: int = 1,
               sequence_parallel: bool = True, kv_dtype=None,
               fsdp_over_pod: bool = True, dp_over_model: bool = False,
               window_ring: bool = False, ssm_chunk: int = 0,
               kv_shard: str = "all"):
    """Returns (fn, args, in_shardings) ready to lower."""
    import dataclasses as _dc
    if ssm_chunk:
        cfg = _dc.replace(cfg, ssm_chunk=ssm_chunk)
    rules = ShardingRules.for_mesh(mesh, sequence_parallel=sequence_parallel,
                                   fsdp_over_pod=fsdp_over_pod,
                                   dp_over_model=dp_over_model)
    if kv_shard != "all":
        # page-heap sharding strategy: 'all' (batch axes + model),
        # 'model' (TP only), 'data' (DP axes only)
        rules.rules["pages"] = (("model",) if kv_shard == "model"
                                else rules.rules["batch"])
    model = build_model(cfg)
    ax = model.logical_axes()
    absp = model.abstract_params()
    psh = rules.param_shardings(ax, absp)

    if shape.kind == "train":
        opt = AdamW(total_steps=1000)
        step = make_train_step(model, opt, remat_policy=remat_policy,
                               microbatches=microbatches, rules=rules)
        state = abstract_state(model, opt)
        st_ax = state_logical_axes(model)
        st_sh = jax.tree.map(
            lambda a, s: NamedSharding(mesh, rules.spec_for(a, s.shape))
            if s is not None else None,
            st_ax, state,
            is_leaf=lambda x: x is None or (isinstance(x, tuple) and
                                            all(isinstance(e, (str, type(None)))
                                                for e in x)))
        batch = shaped_batch(cfg, shape)
        bsh = batch_shardings(rules, batch)
        return step, (state, batch), (st_sh, bsh), rules

    if shape.kind == "prefill":
        def prefill_step(params, batch, caches):
            with use_rules(rules):
                return model.prefill(params, batch, caches,
                                     remat_policy="none")
        b, s = shape.global_batch, shape.seq_len
        kvd = kv_dtype or kv_dtype_for(cfg, s, b)
        caches = model.make_decode_caches(b, max_seq=s, kv_dtype=kvd,
                                          abstract=True,
                                          window_ring=window_ring)
        batch = shaped_batch(cfg, shape)
        batch.pop("targets")
        return (prefill_step, (absp, batch, caches),
                (psh, batch_shardings(rules, batch),
                 cache_shardings(rules, caches)), rules)

    # decode
    def serve_step(params, tokens, caches):
        with use_rules(rules):
            return model.decode_step(params, tokens, caches)
    tokens, caches = decode_inputs(cfg, shape, kv_dtype,
                                   window_ring=window_ring)
    tsh = NamedSharding(mesh, rules.spec_for(("batch", None),
                                             tokens.shape))
    return (serve_step, (absp, tokens, caches),
            (psh, tsh, cache_shardings(rules, caches)), rules)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def _measure(cfg, shape, mesh, *, analysis: bool, kv_dtype=None,
             want_memory: bool = False, **build_kw):
    """Lower+compile one variant; returns cost/collective (+memory) dict.

    ``analysis=True`` unrolls every inner scan (flash blocks, SSD
    chunks) and widens the decode page block to the full table, so HLO
    cost analysis counts every iteration — XLA counts a while body
    exactly once.  Memory numbers always come from analysis=False
    (realistic blocked execution)."""
    from repro.models import layers as Lyr
    Lyr.set_analysis_unroll(analysis)
    KVC.set_page_block_override(10 ** 9 if analysis else None)
    KVC.set_dense_prefill(True)  # canonical page layout in the dry-run
    try:
        fn, args, shardings, _rules = build_cell(cfg, shape, mesh,
                                                 kv_dtype=kv_dtype,
                                                 **build_kw)
        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
        dt = time.time() - t0
        cost = compiled.cost_analysis()
        out = {"flops": float(cost.get("flops", 0.0)),
               "bytes": float(cost.get("bytes accessed", 0.0)),
               "coll": hlo_collective_bytes(compiled.as_text()),
               "seconds": round(dt, 1)}
        if want_memory:
            mem = compiled.memory_analysis()
            out["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0),
            }
        return out
    finally:
        Lyr.set_analysis_unroll(False)
        KVC.set_page_block_override(None)
        KVC.set_dense_prefill(False)


def _probe_plan(cfg: ModelConfig):
    """(probe pairs, unit counts) for trip-count correction of the
    layer scan: corrected = F1 + (units-1)·(F2-F1) [+ tail·rec_unit]."""
    import dataclasses as dc
    if cfg.family == "hybrid":
        ntri, tail = divmod(cfg.num_layers, cfg.attn_period)
        plan = {"main": (dc.replace(cfg, num_layers=cfg.attn_period),
                         dc.replace(cfg, num_layers=2 * cfg.attn_period),
                         ntri)}
        if tail:
            plan["rec"] = (dc.replace(cfg, num_layers=1,
                                      attn_period=10 ** 6),
                           dc.replace(cfg, num_layers=2,
                                      attn_period=10 ** 6),
                           tail)
        return plan
    if cfg.is_encdec:
        import dataclasses as dc
        return {"main": (dc.replace(cfg, num_layers=1, enc_layers=1),
                         dc.replace(cfg, num_layers=2, enc_layers=2),
                         cfg.num_layers)}
    import dataclasses as dc
    return {"main": (dc.replace(cfg, num_layers=1),
                     dc.replace(cfg, num_layers=2), cfg.num_layers)}


_COST_KEYS = ("flops", "bytes")


def _corrected(probes: dict) -> dict:
    """Combine probe measurements into whole-model cost estimates.

    Per-layer units are clamped at 0: XLA occasionally lowers the L=1
    probe with *more* collectives than L=2 (different fusion/CSE
    choices), and a negative per-layer cost would poison the total."""
    main1, main2, units = probes["main"]
    out = {"probe_raw": {"f1": {k: main1[k] for k in _COST_KEYS},
                         "f2": {k: main2[k] for k in _COST_KEYS},
                         "f1_coll": main1["coll"]["total"],
                         "f2_coll": main2["coll"]["total"]}}
    for k in _COST_KEYS:
        unit = max(main2[k] - main1[k], 0.0)
        out[k] = main1[k] + (units - 1) * unit
        out[f"{k}_per_layer"] = unit
    coll = {}
    for k in list(probes["main"][0]["coll"].keys()):
        unit = max(main2["coll"][k] - main1["coll"][k], 0)
        coll[k] = main1["coll"][k] + (units - 1) * unit
    if "rec" in probes:
        r1, r2, tail = probes["rec"]
        for k in _COST_KEYS:
            out[k] += tail * max(r2[k] - r1[k], 0.0)
        for k in coll:
            coll[k] += tail * max(r2["coll"][k] - r1["coll"][k], 0)
    out["coll"] = coll
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = "experiments/dryrun", tag: str = "",
             probes: bool = True, **build_kw):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "devices": int(mesh.devices.size),
           "build_kw": {k: str(v) for k, v in build_kw.items()}}
    kvd = (build_kw.pop("kv_dtype", None)
           or (kv_dtype_for(cfg, shape.seq_len, shape.global_batch)
               if shape.kind in ("prefill", "decode") else None))
    rec["kv_dtype"] = str(kvd) if kvd is not None else None
    try:
        full = _measure(cfg, shape, mesh, analysis=False, kv_dtype=kvd,
                        want_memory=True, **build_kw)
        rec.update(ok=True, memory=full["memory"],
                   compile_s=full["seconds"],
                   raw_cost={"flops": full["flops"],
                             "bytes": full["bytes"],
                             "coll": full["coll"]})
        if probes:
            pl = _probe_plan(cfg)
            meas = {}
            for name, (c1, c2, units) in pl.items():
                f1 = _measure(c1, shape, mesh, analysis=True,
                              kv_dtype=kvd, **build_kw)
                f2 = _measure(c2, shape, mesh, analysis=True,
                              kv_dtype=kvd, **build_kw)
                meas[name] = (f1, f2, units)
            rec["cost"] = _corrected(meas)
            rec["collectives"] = rec["cost"].pop("coll")
        else:
            rec["cost"] = {"flops": full["flops"], "bytes": full["bytes"]}
            rec["collectives"] = full["coll"]
    except Exception as e:  # noqa: BLE001 — recorded, surfaced by caller
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2500:]})
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--dp-over-model", action="store_true")
    ap.add_argument("--window-ring", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--kv-shard", default="all",
                    choices=("all", "model", "data"))
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    kw = dict(remat_policy=args.remat, microbatches=args.microbatches,
              sequence_parallel=not args.no_sp,
              dp_over_model=args.dp_over_model,
              window_ring=args.window_ring, ssm_chunk=args.ssm_chunk,
              kv_shard=args.kv_shard)
    cells = []
    if args.all:
        from repro.configs import ALL_ARCHS
        for a in ALL_ARCHS:
            for sh in cells_for(get_arch(a)):
                cells.append((a, sh.name))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       out_dir=args.out, probes=not args.multi_pod,
                       tag=args.tag, **kw)
        status = "OK " if rec.get("ok") else "FAIL"
        extra = (f"compile={rec.get('compile_s')}s "
                 f"flops/dev={rec.get('cost', {}).get('flops', 0):.3g} "
                 f"coll/dev={rec.get('collectives', {}).get('total', 0):.3g}B "
                 f"peak/dev={rec.get('memory', {}).get('peak_bytes', 0)/2**30:.2f}GiB"
                 if rec.get("ok") else rec.get("error"))
        print(f"[{status}] {arch} × {shape} "
              f"({'2x16x16' if args.multi_pod else '16x16'}): {extra}",
              flush=True)


if __name__ == "__main__":
    main()
