"""End-to-end training driver with checkpoint/restart, preemption
handling, straggler monitoring and (optional) compressed cross-pod
gradient sync.

Runs on whatever devices exist: real hardware uses the production mesh
shardings; this container runs the same code on a 1-device mesh (or a
forced multi-device host mesh via --fake-devices N for integration
tests).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 20 --ckpt-dir /tmp/run1 [--resume]
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.fake_devices}")

    import jax
    import numpy as np
    from repro.ckpt import checkpoint as CK
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataConfig, batch_at
    from repro.ft.runtime import PreemptionGuard, StepMonitor
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.parallel.sharding import ShardingRules
    from repro.train.optimizer import AdamW
    from repro.train.train_step import init_state, make_train_step

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    dcfg = DataConfig(seed=args.seed)

    mesh = make_host_mesh()
    rules = ShardingRules.for_mesh(mesh)
    opt = AdamW(peak_lr=args.lr, warmup_steps=5, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(
        model, opt, remat_policy=args.remat,
        microbatches=args.microbatches, rules=rules))

    state = init_state(model, jax.random.PRNGKey(args.seed), opt)
    start_step = 0
    ckpt = CK.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and CK.latest_step(args.ckpt_dir) is not None:
        state, start_step = CK.restore(state, args.ckpt_dir)
        print(f"resumed from step {start_step}", flush=True)

    guard = PreemptionGuard()
    mon = StepMonitor()
    for step in range(start_step, args.steps):
        mon.start()
        batch = jax.tree.map(
            lambda x: jax.numpy.asarray(x),
            batch_at(cfg, shape, dcfg, step))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        m = mon.stop()
        print(f"step {step:5d} loss {loss:8.4f} "
              f"gnorm {float(metrics['grad_norm']):7.3f} "
              f"t {m['step_time']:6.2f}s"
              + (" [straggler]" if m["straggler"] else ""), flush=True)
        if not np.isfinite(loss):
            print("non-finite loss; aborting", file=sys.stderr)
            return 1
        want_ckpt = ckpt and ((step + 1) % args.ckpt_every == 0
                              or guard.should_stop
                              or step + 1 == args.steps)
        if want_ckpt:
            ckpt.save(state, step + 1)
        if guard.should_stop:
            print("preempted: checkpoint flushed, exiting", flush=True)
            break
    if ckpt:
        ckpt.wait()
    return 0


if __name__ == "__main__":
    sys.exit(main())
