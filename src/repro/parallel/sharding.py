"""Logical-axis sharding rules: FSDP × TP × SP on the (pod, data, model)
production mesh.

Params carry logical axis names from their Specs (models/params.py);
the rules here map them to mesh axes:

    vocab / heads / kv_heads / mlp / ssm_inner / expert → 'model'   (TP)
    embed                                               → FSDP axes (ZeRO-3)
    batch (activations)                                 → ('pod', 'data')
    seq   (activations, train/prefill)                  → 'model'   (SP)

A mapping is applied only when the dimension is at least the axis size
(GSPMD pads non-divisible shards; ≤2× padding is accepted, e.g. 40
heads over 16 ways → pad to 48).  Tiny dims (kv_heads=2 on a 16-way
axis) stay replicated rather than paying 8× padding.

``constrain`` is the activation-sharding hook the model code calls; it
is a no-op outside a ``use_rules`` scope, so single-device tests and
benches run unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    # logical name -> mesh axis (or tuple of axes)
    rules: dict

    @classmethod
    def for_mesh(cls, mesh: Mesh, *, sequence_parallel: bool = True,
                 fsdp_over_pod: bool = True,
                 dp_over_model: bool = False) -> "ShardingRules":
        """``dp_over_model``: small attention-free models (mamba2) have
        tiny params and sequence-hostile recurrences — the model axis
        joins data parallelism (batch over every axis, params FSDP over
        'data' only, no TP/SP)."""
        has_pod = "pod" in mesh.axis_names
        fsdp = (("pod", "data") if (has_pod and fsdp_over_pod)
                else ("data",))
        batch = ("pod", "data") if has_pod else ("data",)
        if dp_over_model:
            batch = batch + ("model",)
            sequence_parallel = False
        rules = {
            "vocab": "model",
            "embed": fsdp,
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "ssm_inner": "model",
            "expert": "model",
            "layers": None,
            # activations
            "batch": batch,
            "seq": "model" if sequence_parallel else None,
            "act_embed": None,
            "pages": batch + ("model",),  # KV page heaps: fully sharded
            "kv_pages_model": "model",
        }
        return cls(mesh=mesh, rules=rules)

    def axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        n = 1
        for a in mesh_axes:
            n *= self.mesh.shape[a]
        return n

    def spec_for(self, logical: Tuple[Optional[str], ...],
                 shape: Optional[Tuple[int, ...]] = None) -> P:
        """Map logical axes to a PartitionSpec, dropping mappings whose
        dim is smaller than the axis group (padding > 2×)."""
        out, used = [], set()
        for i, name in enumerate(logical):
            mesh_axes = self.rules.get(name) if name else None
            if mesh_axes is None:
                out.append(None)
                continue
            axes = ((mesh_axes,) if isinstance(mesh_axes, str)
                    else tuple(mesh_axes))
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                out.append(None)
                continue
            # jit in_shardings demand exact divisibility; shrink the
            # axis tuple from the right until the dim divides (e.g.
            # batch=256 over (pod,data,model)=512 → (pod,data)=32).
            while axes and shape is not None \
                    and shape[i] % self.axis_size(axes) != 0:
                axes = axes[:-1]
            if not axes:
                out.append(None)
                continue
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def param_shardings(self, logical_tree, abstract_tree):
        """NamedShardings for a param pytree (abstract_tree supplies
        shapes for the divisibility guard)."""
        def one(axes, sds):
            return NamedSharding(self.mesh, self.spec_for(axes, sds.shape))
        return jax.tree.map(one, logical_tree, abstract_tree,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and all(isinstance(e, (str, type(None)))
                                    for e in x))


_ACTIVE: list = []


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def current_rules() -> Optional[ShardingRules]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, *logical):
    """Annotate activation sharding (no-op without active rules)."""
    rules = current_rules()
    if rules is None or x is None:
        return x
    spec = rules.spec_for(tuple(logical), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def host_local_batch_size(global_batch: int, mesh: Mesh) -> int:
    n = np.prod([mesh.shape[a] for a in mesh.axis_names
                 if a in ("pod", "data")])
    return max(1, global_batch // int(n))
