"""Transformer building blocks: norms, RoPE/M-RoPE, GQA flash attention
(causal / sliding-window / cross), gated MLPs.

Attention is a blockwise online-softmax scan over KV (pure-jnp flash):
memory is O(S·block) instead of O(S²), which is what lets prefill_32k
and train_4k lower without materializing score matrices.  Each scan
body is rematerialized, so autodiff recomputes block scores backward —
flash-attention backward complexity, in plain JAX.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec

_NEG = -1e30

# Analysis mode: fully unroll inner scans so compiled-HLO cost analysis
# counts every iteration (XLA counts a while body once).  Set by
# launch/dryrun.py around lowering; never on in training/tests.
_ANALYSIS_UNROLL = False


def set_analysis_unroll(v: bool):
    global _ANALYSIS_UNROLL
    _ANALYSIS_UNROLL = bool(v)


def scan_unroll():
    return _ANALYSIS_UNROLL


# ---- norms -----------------------------------------------------------------

def norm_spec(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": Spec((d,), ("embed",), "ones"),
                "bias": Spec((d,), ("embed",), "zeros")}
    return {"scale": Spec((d,), ("embed",), "ones")}


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---- RoPE / M-RoPE ---------------------------------------------------------

def rope_freqs(cfg: ModelConfig):
    hd = cfg.head_dim_
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(cfg: ModelConfig, x, positions):
    """x: (B, S, H, D).  positions: (B, S) int32, or (3, B, S) for M-RoPE
    (temporal/height/width sections, Qwen2-VL §2.1)."""
    inv = rope_freqs(cfg)  # (D/2,)
    if cfg.mrope_sections is not None:
        # frequency slot j rotates by the position stream (temporal /
        # height / width) that owns its section.
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        sec = jnp.concatenate([
            jnp.full((n,), i, jnp.int32)
            for i, n in enumerate(cfg.mrope_sections)])  # (D/2,)
        pos = positions[sec]  # (D/2, B, S)
        ang = pos.transpose(1, 2, 0).astype(jnp.float32) * inv[None, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# ---- blockwise flash attention (pure jnp) ----------------------------------

def flash_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset=0, kv_valid_len=None, block: int = 512,
                    block_q: int = 4096):
    """q: (B, S, Hq, D); k, v: (B, T, Hkv, D).  GQA via head grouping.

    ``q_offset``: global position of q[0] relative to k[0] (decode /
    chunked prefill).  ``window``: sliding-window width (None = full).
    ``kv_valid_len``: (B,) valid kv length (padding mask).
    Long sequences are additionally blocked over q (``block_q``) so the
    live score/accumulator tensors stay O(block_q·block), not O(S·block)
    — prefill_32k peaked at 61 GiB/chip without it.
    """
    B, S, Hq, D = q.shape
    if S > block_q:
        nq = -(-S // block_q)
        pad = nq * block_q - S
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
        qb = qp.reshape(B, nq, block_q, Hq, D).transpose(1, 0, 2, 3, 4)
        offs = q_offset + jnp.arange(nq, dtype=jnp.int32) * block_q

        def one(args):
            qi, oi = args
            return flash_attention(qi, k, v, causal=causal, window=window,
                                   q_offset=oi, kv_valid_len=kv_valid_len,
                                   block=block, block_q=S)

        out = jax.lax.map(one, (qb, offs))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * block_q, Hq, D)
        return out[:, :S]
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block = min(block, T)
    nblocks = -(-T // block)
    pad = nblocks * block - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblocks, block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblocks, block, Hkv, D).transpose(1, 0, 2, 3, 4)

    stage_dt = jnp.float32 if q.dtype == jnp.float32 else jnp.bfloat16
    qg = (q.reshape(B, S, Hkv, G, D) * (D ** -0.5)).astype(stage_dt)
    qpos = q_offset + jnp.arange(S, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        i, kblk, vblk = inp
        kpos = i * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kblk.astype(stage_dt),
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((S, block), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        mask &= (kpos < T)[None, :]
        mask = mask[None, None, None]  # (1, 1, 1, S, block)
        if kv_valid_len is not None:
            mask = mask & (kpos[None, None, None, None, :]
                           < kv_valid_len[:, None, None, None, None])
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        acc_new = alpha[..., None] * acc + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(stage_dt), vblk.astype(stage_dt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, S), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, D), jnp.float32)
    idx = jnp.arange(nblocks, dtype=jnp.int32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), (m0, l0, a0),
        (idx, kb, vb), unroll=_ANALYSIS_UNROLL)
    out = acc / (l[..., None] + 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)


# ---- attention block --------------------------------------------------------

def attn_specs(cfg: ModelConfig):
    """(heads, head_dim) stored MERGED: heads×hd is divisible by the
    16-way model axis for every assigned arch even when the head count
    (40, 14, 12…) is not — jit in_shardings demands exact divisibility."""
    hd, d = cfg.head_dim_, cfg.d_model
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    s = {
        "wq": Spec((d, nq), ("embed", "heads")),
        "wk": Spec((d, nkv), ("embed", "heads")),
        "wv": Spec((d, nkv), ("embed", "heads")),
        "wo": Spec((nq, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((nq,), ("heads",), "zeros")
        s["bk"] = Spec((nkv,), ("heads",), "zeros")
        s["bv"] = Spec((nkv,), ("heads",), "zeros")
    return s


def qkv_project(cfg: ModelConfig, p, x, positions, *, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if rope:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    return q, k, v


def attn_out(p, o, dtype):
    B, S = o.shape[:2]
    return o.astype(dtype).reshape(B, S, -1) @ p["wo"].astype(dtype)


# ---- gated MLP ---------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": Spec((d, f), ("embed", "mlp")),
        "w_up": Spec((d, f), ("embed", "mlp")),
        "w_down": Spec((f, d), ("mlp", "embed")),
    }


def apply_mlp(cfg: ModelConfig, p, x):
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = act(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    return (g * u) @ p["w_down"].astype(x.dtype)
