"""Encoder-decoder assembly (seamless-m4t): bidirectional encoder over
stubbed frame embeddings + causal decoder with cross-attention.

Decode caches: paged self-attention KV (grows per generated token, on
the allocator) + dense cross-attention KV (computed once at prefill
from the encoder output — fixed size, so it stays a plain tensor)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as Lyr
from repro.models import params as Prm
from repro.models.params import Spec
from repro.models.transformer import Caches, unembed
from repro.paged import kv_cache as KV
from repro.parallel.sharding import constrain


class EncDecCaches(NamedTuple):
    self_kv: Optional[KV.PagedKV]     # decoder self-attn, paged
    cross_k: Optional[Any]            # (Ld, B, Se, Hkv, hd)
    cross_v: Optional[Any]
    enc_valid: Optional[Any]          # (B,) encoder valid lengths


def enc_block_specs(cfg: ModelConfig):
    return {"norm1": Lyr.norm_spec(cfg), "attn": Lyr.attn_specs(cfg),
            "norm2": Lyr.norm_spec(cfg), "ffn": Lyr.mlp_specs(cfg)}


def dec_block_specs(cfg: ModelConfig):
    return {"norm1": Lyr.norm_spec(cfg), "attn": Lyr.attn_specs(cfg),
            "norm_x": Lyr.norm_spec(cfg), "xattn": Lyr.attn_specs(cfg),
            "norm2": Lyr.norm_spec(cfg), "ffn": Lyr.mlp_specs(cfg)}


def encdec_specs(cfg: ModelConfig):
    v, d = cfg.padded_vocab, cfg.d_model
    s = {
        "embed": Spec((v, d), ("vocab", "embed")),
        "enc_in": Spec((d, d), ("embed", None)),  # frame-embedding adapter
        "enc_blocks": Prm.stack(enc_block_specs(cfg), cfg.enc_layers),
        "enc_norm": Lyr.norm_spec(cfg),
        "dec_blocks": Prm.stack(dec_block_specs(cfg), cfg.num_layers),
        "final_norm": Lyr.norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((d, v), ("embed", "vocab"))
    return s


def encode(cfg, params, src_embeds, remat_policy="full",
           dtype=jnp.bfloat16):
    """src_embeds: (B, Se, D) stubbed modality frontend output."""
    x = (src_embeds.astype(dtype) @ params["enc_in"].astype(dtype))
    B, Se, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))

    def body(x, p_l):
        x = constrain(x, "batch", "seq", "act_embed")
        h = Lyr.apply_norm(cfg, p_l["norm1"], x)
        q, k, v = Lyr.qkv_project(cfg, p_l["attn"], h, pos)
        o = Lyr.flash_attention(q, k, v, causal=False)
        x = x + Lyr.attn_out(p_l["attn"], o, x.dtype)
        h = Lyr.apply_norm(cfg, p_l["norm2"], x)
        return x + Lyr.apply_mlp(cfg, p_l["ffn"], h), None

    from repro.models.transformer import _remat
    x, _ = jax.lax.scan(_remat(body, remat_policy), x,
                        params["enc_blocks"], unroll=Lyr.scan_unroll())
    return Lyr.apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg, p_l, enc_out):
    """Cross-attention K/V from encoder output (no RoPE)."""
    B, S, _ = enc_out.shape
    k = enc_out @ p_l["wk"].astype(enc_out.dtype)
    v = enc_out @ p_l["wv"].astype(enc_out.dtype)
    if cfg.qkv_bias:
        k = k + p_l["bk"].astype(enc_out.dtype)
        v = v + p_l["bv"].astype(enc_out.dtype)
    hd = cfg.head_dim_
    return (k.reshape(B, S, cfg.num_kv_heads, hd),
            v.reshape(B, S, cfg.num_kv_heads, hd))


def decode_stack(cfg, params, tokens, enc_out, mode,
                 caches: EncDecCaches, remat_policy="full",
                 dtype=jnp.bfloat16, return_hidden: bool = False):
    """Decoder over target tokens.  mode train/prefill: full causal pass
    (cross-attn against enc_out); decode: one token vs caches."""
    B, S = tokens.shape
    x = params["embed"].astype(dtype)[tokens]
    kv = caches.self_kv
    page_table = None if kv is None else kv.page_table
    seq_lens = None if kv is None else kv.seq_lens
    if mode == "decode":
        pos = kv.seq_lens[:, None]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, inp):
        x = constrain(x, "batch", "seq", "act_embed")
        p_l, kv_l, cross = inp
        h = Lyr.apply_norm(cfg, p_l["norm1"], x)
        q, k, v = Lyr.qkv_project(cfg, p_l["attn"], h, pos)
        if mode == "decode":
            kv_l = KV.append1(kv_l, page_table, seq_lens, k, v)
            o = KV.paged_attend1(kv_l, page_table, seq_lens + 1, q)
        else:
            o = Lyr.flash_attention(q, k, v, causal=True)
            if mode == "prefill":
                kv_l = KV.prefill_write1(kv_l, page_table, k, v)
        x = x + Lyr.attn_out(p_l["attn"], o, x.dtype)

        # cross attention
        h = Lyr.apply_norm(cfg, p_l["norm_x"], x)
        qx = h @ p_l["xattn"]["wq"].astype(h.dtype)
        if cfg.qkv_bias:
            qx = qx + p_l["xattn"]["bq"].astype(h.dtype)
        qx = qx.reshape(h.shape[0], h.shape[1], cfg.num_heads, cfg.head_dim_)
        if mode == "decode":
            kx, vx = cross
        else:
            kx, vx = _cross_kv(cfg, p_l["xattn"], enc_out)
        ox = Lyr.flash_attention(qx, kx, vx, causal=False,
                                 kv_valid_len=caches.enc_valid)
        x = x + Lyr.attn_out(p_l["xattn"], ox, x.dtype)

        h = Lyr.apply_norm(cfg, p_l["norm2"], x)
        x = x + Lyr.apply_mlp(cfg, p_l["ffn"], h)
        return x, (kv_l, kx, vx)

    from repro.models.transformer import _remat
    kv_xs = None if kv is None else kv.layers
    cross_xs = ((caches.cross_k, caches.cross_v) if mode == "decode"
                else (None, None))
    x, (kv_layers, ck, cv) = jax.lax.scan(
        _remat(body, remat_policy), x, (params["dec_blocks"], kv_xs,
                                        cross_xs), unroll=Lyr.scan_unroll())
    new_kv = None if kv is None else kv._replace(layers=kv_layers)
    new = EncDecCaches(self_kv=new_kv, cross_k=ck, cross_v=cv,
                       enc_valid=caches.enc_valid)
    if mode == "prefill":
        x = x[:, -1:]  # only the last position's logits are consumed
    if return_hidden:
        return Lyr.apply_norm(cfg, params["final_norm"], x), new
    return unembed(cfg, params, x), new
