"""RG-LRU recurrent block (RecurrentGemma / Griffin) for the hybrid arch.

Griffin's recurrent temporal-mixing block: two input branches — a GeLU
gate and a (causal conv → RG-LRU) stream — merged multiplicatively and
projected out.  The RG-LRU recurrence

    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t),
    a_t = exp(c · r_t · log_a),  log_a = −softplus(Λ)

is a first-order linear recurrence, so training/prefill uses
``jax.lax.associative_scan`` (O(log S) depth, TPU-friendly); decode is
the O(1) step.  The hybrid stack runs this for 2 of every 3 layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec

_C = 8.0


def rglru_specs(cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.lru_width or d
    return {
        "w_gate_branch": Spec((d, r), ("embed", "mlp")),
        "w_rec_branch": Spec((d, r), ("embed", "mlp")),
        "conv_w": Spec((4, r), (None, "mlp"), scale=1.0 / math.sqrt(4)),
        "conv_b": Spec((r,), ("mlp",), "zeros"),
        "w_input_gate": Spec((r, r), ("mlp", None)),
        "w_rec_gate": Spec((r, r), ("mlp", None)),
        "lambda_p": Spec((r,), ("mlp",), "const", scale=1.0),
        "w_out": Spec((r, d), ("mlp", "embed")),
    }


def _conv(x, w, b, state=None):
    width = w.shape[0]
    ctx = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([ctx, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    return y + b[None, None, :], xp[:, -(width - 1):, :]


def apply_rglru_layer(cfg: ModelConfig, p, x, cache=None):
    """x: (B, S, D); cache: None or (h (B, R) f32, conv_state).
    Returns (y (B, S, D), new_cache)."""
    B, S, D = x.shape
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(x.dtype))
    u = x @ p["w_rec_branch"].astype(x.dtype)
    conv_state = None if cache is None else cache[1]
    u, new_conv = _conv(u, p["conv_w"].astype(x.dtype),
                        p["conv_b"].astype(x.dtype), conv_state)

    uf = u.astype(jnp.float32)
    r_t = jax.nn.sigmoid(uf @ p["w_rec_gate"].astype(jnp.float32))
    i_t = jax.nn.sigmoid(uf @ p["w_input_gate"].astype(jnp.float32))
    log_a = -jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
    a_t = jnp.exp(_C * r_t * log_a[None, None, :])          # (B, S, R)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t ** 2, 1e-12)) * (i_t * uf)

    if cache is None:
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a2 * a1, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
        h_last = h[:, -1]
    else:
        h0 = cache[0]
        h = (a_t[:, 0] * h0 + b_t[:, 0])[:, None]
        h_last = h[:, 0]

    y = (gate * h.astype(x.dtype)) @ p["w_out"].astype(x.dtype)
    return y, (h_last, new_conv)
