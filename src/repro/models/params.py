"""Parameter-spec system: one source of truth for shape, init and
logical sharding axes.

Modules declare pytrees of ``Spec``; ``materialize`` turns them into
arrays (deterministic per-leaf PRNG via path folding) and
``logical_axes`` extracts the matching pytree of logical-axis tuples
that parallel/sharding.py maps onto the mesh.  The dry-run never
materializes — it uses ``abstract`` (ShapeDtypeStruct only).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == ndim
    init: str = "normal"             # normal | zeros | ones | small
    scale: float = 1.0
    dtype: object = jnp.float32


def _is_spec(x):
    return isinstance(x, Spec)


def _leaf_key(key, path):
    # zlib.crc32, not hash(): python string hashing is randomized per
    # process, which would make init non-reproducible across runs.
    import zlib
    name = "/".join(str(p) for p in path)
    return jax.random.fold_in(key, zlib.crc32(name.encode()) % (2**31))


def materialize(specs, key):
    def make(path, s: Spec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "const":
            return jnp.full(s.shape, s.scale, s.dtype)
        k = _leaf_key(key, path)
        fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[-1], 1)
        std = s.scale / (fan_in ** 0.5)
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(
            s.dtype)
    return jax.tree_util.tree_map_with_path(make, specs,
                                            is_leaf=_is_spec)


def abstract(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=_is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def stack(specs, n: int):
    """Prepend a scanned 'layers' dimension to every spec in the tree."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init,
                       s.scale, s.dtype),
        specs, is_leaf=_is_spec)


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total
