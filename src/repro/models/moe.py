"""Mixture-of-Experts FFN with capacity-based dispatch.

Token→expert routing reuses the allocator's lane-aggregation machinery
(``groups.masked_rank``): each (token, k) pair is an *allocation
request* against its expert's capacity-C buffer, ranked per expert in
one masked prefix-sum; rank ≥ C means the request fails and the token
is dropped for that expert — the exact failure semantics of a bulk
``Ouroboros.alloc``.  This keeps MoE fully shardable: the buffers are
dense (E, C, D) tensors (E over 'model' when divisible — phi3.5's 16
experts shard exactly; otherwise d_ff takes the TP axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import groups
from repro.models.params import Spec
from repro.parallel.sharding import constrain


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": Spec((d, e), ("embed", None)),
        "w_gate": Spec((e, d, f), ("expert", "embed", "mlp")),
        "w_up": Spec((e, d, f), ("expert", "embed", "mlp")),
        "w_down": Spec((e, f, d), ("expert", "mlp", "embed")),
    }


def apply_moe(cfg: ModelConfig, p, x, no_drop: bool = False):
    """x: (B, S, D) → (y, aux_loss).  Top-k routing, *per-batch-row*
    capacity (C = cf·S·K/E per row) so the dispatch buffer (B, E, C, D)
    shards over the data axes with zero dispatch collectives — a
    globally-ranked buffer defeats GSPMD and replicates terabytes.
    ``no_drop``: decode path — capacity covers the worst case so no
    token is ever dropped at inference."""
    B, S, D = x.shape
    K, E = cfg.num_experts_per_tok, cfg.num_experts
    cap = S * K if no_drop else max(1, int(cfg.moe_capacity_factor
                                           * S * K / E))
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)       # (B, S, E)
    topw, topi = jax.lax.top_k(probs, K)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)

    flat_e = topi.reshape(B, S * K)               # (B, S·K)
    flat_w = topw.reshape(B, S * K)
    # rank within (row, expert): lane-aggregated allocation per row
    onehot = (flat_e[..., None]
              == jnp.arange(E, dtype=jnp.int32)[None, None, :])
    inc = jnp.cumsum(onehot.astype(jnp.int32), axis=1)
    rank = jnp.take_along_axis(inc - onehot.astype(jnp.int32),
                               flat_e[..., None], axis=2)[..., 0]
    keep = rank < cap                              # capacity = alloc success

    tok_of = jnp.arange(S * K, dtype=jnp.int32) // K
    src = x[:, tok_of]                             # static-index gather
    # Dispatch/combine as *vmapped* per-row scatter/gather: the batch
    # dim becomes a scatter batching dim, which GSPMD partitions along
    # 'data'.  A flat multi-index scatter is unpartitionable and gets
    # replicated with operand-shaped index tensors (observed 118 GiB
    # and 40 GiB u32 iotas per chip on mixtral×train_4k).

    def disp(e_b, r_b, keep_b, src_b):
        return jnp.zeros((E, cap, D), x.dtype).at[
            jnp.where(keep_b, e_b, E), r_b].set(src_b, mode="drop")

    buf = jax.vmap(disp)(flat_e, rank, keep, src)
    buf = constrain(buf, "batch", "expert", None, "act_embed")

    g = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    g = constrain(g, "batch", "expert", None, "mlp")
    u = constrain(u, "batch", "expert", None, "mlp")
    y_e = jnp.einsum("becf,efd->becd", g * u, p["w_down"].astype(x.dtype))
    y_e = constrain(y_e, "batch", "expert", None, "act_embed")

    gathered = jax.vmap(
        lambda ye_b, e_b, r_b: ye_b.at[e_b, r_b].get(
            mode="fill", fill_value=0))(y_e, flat_e, rank)
    y = (gathered * (keep[..., None])
         * flat_w[..., None].astype(x.dtype)).reshape(B, S, K, D).sum(axis=2)

    # Switch-transformer load-balance loss: E * Σ_e f_e · P_e
    f_e = jnp.zeros(E, jnp.float32).at[flat_e.reshape(-1)].add(
        jnp.where(keep.reshape(-1), 1.0, 0.0)) / (B * S * K)
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return y, aux
