"""Mamba-2 block (SSD — state-space duality) for mamba2-780m.

Training/prefill uses the chunked dual form (sequential lax.scan over
chunks carrying the (H, P, N) state — same math as kernels/ssd_scan.py,
which is the TPU Pallas fast path).  Decode is the O(1) recurrence on a
persistent state — the reason this arch runs long_500k.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec


class SSMCache(NamedTuple):
    h: jnp.ndarray           # (L, B, H, P, N) float32
    conv: jnp.ndarray        # (L, B, conv-1, conv_dim)


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def ssm_specs(cfg: ModelConfig):
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
    cd = conv_dim(cfg)
    return {
        # order: [z (di) | x (di) | B (g*n) | C (g*n) | dt (h)]
        "in_proj": Spec((d, 2 * di + 2 * g * n + h), ("embed", "ssm_inner")),
        "conv_w": Spec((w, cd), (None, "ssm_inner"),
                       scale=1.0 / math.sqrt(w)),
        "conv_b": Spec((cd,), ("ssm_inner",), "zeros"),
        "a_log": Spec((h,), (None,), "const", scale=math.log(4.0)),
        "dt_bias": Spec((h,), (None,), "const", scale=-3.0),
        "d_skip": Spec((h,), (None,), "ones"),
        "norm_scale": Spec((di,), ("ssm_inner",), "ones"),
        "out_proj": Spec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over time.  x: (B, S, C); w: (W, C).
    ``state``: (B, W-1, C) left context (decode).  Returns (y, new_state)."""
    width = w.shape[0]
    ctx = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([ctx, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else ctx
    return y + b[None, None, :], new_state


def ssd_jnp(x, dt, a, b, c, chunk, h0=None):
    """Chunked SSD (pure jnp mirror of kernels/ssd_scan.py).

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, G, N).
    Sequential scan over L//chunk chunks; per-chunk work is MXU matmuls.
    Returns (y (B, L, H, P) f32, h_final (B, H, P, N) f32)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    q = chunk
    pad = (-L) % q
    if pad:
        # dt = 0 on padding ⇒ decay 1, zero input: mathematically inert.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // q

    xr = x.reshape(B, nc, q, H, P).astype(jnp.float32)
    dtr = dt.reshape(B, nc, q, H).astype(jnp.float32)
    br = b.reshape(B, nc, q, G, N).astype(jnp.float32)
    cr = c.reshape(B, nc, q, G, N).astype(jnp.float32)
    dta = dtr * a[None, None, None, :]
    cum = jnp.cumsum(dta, axis=2)                    # (B, nc, q, H)
    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))

    tri = jnp.tril(jnp.ones((q, q), bool))

    def body(h, inp):
        xc, dtc, bc, cc, cumc = inp                  # leading dim B
        decay = jnp.exp(cumc[:, :, None, :] - cumc[:, None, :, :])
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("bqgn,bkgn->bqkg", cc, bc)
        cb = jnp.repeat(cb, rep, axis=3)             # (B, q, q, H)
        w = cb * decay * dtc[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", w, xc)
        # inter-chunk: y_i += exp(cum_i) C_i^T h_in
        cch = jnp.repeat(cc, rep, axis=2)            # (B, q, H, N)
        y = y + jnp.exp(cumc)[..., None] * jnp.einsum(
            "bqhn,bhpn->bqhp", cch, h)
        # state update
        wj = jnp.exp(cumc[:, -1:, :] - cumc) * dtc   # (B, q, H)
        bch = jnp.repeat(bc, rep, axis=2)            # (B, q, H, N)
        h = (jnp.exp(cumc[:, -1, :])[:, :, None, None] * h
             + jnp.einsum("bqhp,bqhn->bhpn", xc * wj[..., None], bch))
        return h, y

    xs = (xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
          br.transpose(1, 0, 2, 3, 4), cr.transpose(1, 0, 2, 3, 4),
          cum.transpose(1, 0, 2, 3))
    from repro.models.layers import scan_unroll
    hf, ys = jax.lax.scan(body, h0, xs, unroll=scan_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H, P)[:, :L]
    return y, hf


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, g, n, h = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                   cfg.ssm_nheads)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt = zxbcdt[..., di + di + 2 * g * n:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def apply_ssm_layer(cfg: ModelConfig, p, x, cache=None):
    """One Mamba-2 mixing layer.  x: (B, S, D).
    cache: None (training/prefill from scratch) or (h, conv_state) for
    single-token decode.  Returns (y, new_cache)."""
    B, S, D = x.shape
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    H, P = cfg.ssm_nheads, cfg.ssm_headdim

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_state = None if cache is None else cache[1]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di]
    b = xbc[..., di:di + g * n].reshape(B, S, g, n)
    c = xbc[..., di + g * n:].reshape(B, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, P)

    if cache is None:
        y, hf = ssd_jnp(xh, dt, a, b, c, cfg.ssm_chunk)
    else:
        h0 = cache[0]
        # O(1) decode recurrence (S == 1)
        decay = jnp.exp(dt[:, 0] * a[None, :])       # (B, H)
        rep = H // g
        bh = jnp.repeat(b[:, 0], rep, axis=1)        # (B, H, N)
        ch = jnp.repeat(c[:, 0], rep, axis=1)
        hf = (h0 * decay[:, :, None, None]
              + (dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32))[..., None]
              * bh[:, :, None, :].astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", hf,
                       ch.astype(jnp.float32))[:, None]

    y = y + p["d_skip"][None, None, :, None].astype(jnp.float32) \
        * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-6)
         * p["norm_scale"][None, None, :]).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (hf, new_conv)
