"""Decoder-only LM assembly: dense / MoE / SSM / hybrid stacks.

Layers are *scanned* (params stacked on a leading 'layers' axis) so a
64-layer model traces one layer body — compile time and HLO size stay
flat with depth, and the FSDP all-gathers pipeline across the scan.
``mode`` selects the path:

    train    — full-sequence mixing, no cache
    prefill  — full-sequence mixing + write paged-KV / final states
    decode   — one token against the caches (paged attention / O(1)
               recurrences)

Caches ride through the scan as per-layer xs/ys (KVLayer arrays are
stacked on the same leading axis as params).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as Lyr
from repro.models import moe as Moe
from repro.models import params as Prm
from repro.models import rglru as Rgl
from repro.models import ssm as Ssm
from repro.models.params import Spec
from repro.paged import kv_cache as KV
from repro.parallel.sharding import constrain


class Caches(NamedTuple):
    """Decode-time state, all stacked over their layer population."""
    kv: Optional[KV.PagedKV] = None      # attention layers
    ssm_h: Optional[Any] = None          # (Lr, B, H, P, N) f32
    ssm_conv: Optional[Any] = None       # (Lr, B, W-1, conv_dim)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _ffn_specs(cfg: ModelConfig):
    if cfg.num_experts:
        return Moe.moe_specs(cfg)
    return Lyr.mlp_specs(cfg)


def block_specs(cfg: ModelConfig):
    if cfg.family == "ssm":
        return {"norm": Lyr.norm_spec(cfg), "mixer": Ssm.ssm_specs(cfg)}
    s = {"norm1": Lyr.norm_spec(cfg), "attn": Lyr.attn_specs(cfg),
         "ffn": _ffn_specs(cfg)}
    if not cfg.parallel_block:
        s["norm2"] = Lyr.norm_spec(cfg)
    return s


def hybrid_triple_specs(cfg: ModelConfig):
    rec = {"norm1": Lyr.norm_spec(cfg), "mixer": Rgl.rglru_specs(cfg),
           "norm2": Lyr.norm_spec(cfg), "ffn": Lyr.mlp_specs(cfg)}
    att = {"norm1": Lyr.norm_spec(cfg), "attn": Lyr.attn_specs(cfg),
           "norm2": Lyr.norm_spec(cfg), "ffn": Lyr.mlp_specs(cfg)}
    return {"rec1": rec, "rec2": rec, "attn": att}


def lm_specs(cfg: ModelConfig):
    v, d = cfg.padded_vocab, cfg.d_model
    s = {"embed": Spec((v, d), ("vocab", "embed")),
         "final_norm": Lyr.norm_spec(cfg)}
    if cfg.family == "hybrid":
        ntri, tail = divmod(cfg.num_layers, cfg.attn_period)
        s["triples"] = Prm.stack(hybrid_triple_specs(cfg), ntri)
        if tail:
            rec = hybrid_triple_specs(cfg)["rec1"]
            s["tail"] = Prm.stack(rec, tail)
    else:
        s["blocks"] = Prm.stack(block_specs(cfg), cfg.num_layers)
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((d, v), ("embed", "vocab"))
    return s


def num_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_period
    return cfg.num_layers


def num_rec_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return cfg.num_layers
    if cfg.family == "hybrid":
        return cfg.num_layers - cfg.num_layers // cfg.attn_period
    return 0


# ---------------------------------------------------------------------------
# sub-blocks (single layer)
# ---------------------------------------------------------------------------

def _attn_mix(cfg, p, x, positions, mode, kvl, page_table, seq_lens,
              window):
    q, k, v = Lyr.qkv_project(cfg, p, x, positions)
    # windowed layers use ring page tables when the table is smaller
    # than the sequence needs (window-bounded KV — pages recycle).
    ring = (window is not None and page_table is not None
            and mode in ("decode", "prefill"))
    if mode == "decode":
        kvl = KV.append1(kvl, page_table, seq_lens, k, v, ring=ring)
        o = KV.paged_attend1(kvl, page_table, seq_lens + 1, q,
                             window=window, ring=ring)
    else:
        o = Lyr.flash_attention(q, k, v, causal=True, window=window)
        if mode == "prefill":
            kvl = KV.prefill_write1(kvl, page_table, k, v, ring=ring)
    return Lyr.attn_out(p, o, x.dtype), kvl


def _ffn(cfg, p, x, mode="train"):
    if cfg.num_experts:
        return Moe.apply_moe(cfg, p, x, no_drop=(mode == "decode"))
    return Lyr.apply_mlp(cfg, p, x), jnp.float32(0.0)


def dense_block(cfg, p, x, positions, mode, kvl, page_table, seq_lens):
    window = cfg.sliding_window
    if cfg.parallel_block:
        h = Lyr.apply_norm(cfg, p["norm1"], x)
        a, kvl = _attn_mix(cfg, p["attn"], h, positions, mode, kvl,
                           page_table, seq_lens, window)
        f, aux = _ffn(cfg, p["ffn"], h, mode)
        return x + a + f, kvl, aux
    h = Lyr.apply_norm(cfg, p["norm1"], x)
    a, kvl = _attn_mix(cfg, p["attn"], h, positions, mode, kvl,
                       page_table, seq_lens, window)
    x = x + a
    h = Lyr.apply_norm(cfg, p["norm2"], x)
    f, aux = _ffn(cfg, p["ffn"], h, mode)
    return x + f, kvl, aux


def ssm_block(cfg, p, x, mode, cache):
    h = Lyr.apply_norm(cfg, p["norm"], x)
    y, new_cache = Ssm.apply_ssm_layer(
        cfg, p["mixer"], h, cache if mode == "decode" else None)
    return x + y, new_cache


def rec_block(cfg, p, x, mode, cache):
    h = Lyr.apply_norm(cfg, p["norm1"], x)
    y, new_cache = Rgl.apply_rglru_layer(
        cfg, p["mixer"], h, cache if mode == "decode" else None)
    x = x + y
    h = Lyr.apply_norm(cfg, p["norm2"], x)
    return x + Lyr.apply_mlp(cfg, p["ffn"], h), new_cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _remat(fn, policy):
    if policy == "none":
        return fn
    pol = None if policy == "full" else getattr(
        jax.checkpoint_policies, policy)
    return jax.checkpoint(fn, policy=pol, prevent_cse=False)


def uniform_stack(cfg, params, x, positions, mode, caches: Caches,
                  remat_policy="full"):
    """dense / moe / ssm: scan over the stacked blocks."""
    kv = caches.kv

    if cfg.family == "ssm":
        def body(carry, inp):
            x, aux = carry
            x = constrain(x, "batch", "seq", "act_embed")
            p_l, (h_l, conv_l) = inp
            cache = (h_l, conv_l) if mode == "decode" else None
            x, new_cache = ssm_block(cfg, p_l, x, mode, cache)
            return (x, aux), new_cache
        # non-decode: dummy per-layer placeholders (states still come back
        # stacked as ys, which is how prefill seeds the decode caches)
        xs_cache = ((caches.ssm_h, caches.ssm_conv) if mode == "decode"
                    else (jnp.zeros((cfg.num_layers, 1)),
                          jnp.zeros((cfg.num_layers, 1))))
        (x, aux), st = jax.lax.scan(
            _remat(body, remat_policy), (x, jnp.float32(0.0)),
            (params["blocks"], xs_cache), unroll=Lyr.scan_unroll())
        new = Caches(kv=None, ssm_h=st[0], ssm_conv=st[1])
        return x, aux, new

    page_table = None if kv is None else kv.page_table
    seq_lens = None if kv is None else kv.seq_lens

    def body(carry, inp):
        x, aux = carry
        x = constrain(x, "batch", "seq", "act_embed")
        p_l, kv_l = inp
        x, kv_l, a = dense_block(cfg, p_l, x, positions, mode, kv_l,
                                 page_table, seq_lens)
        return (x, aux + a), kv_l

    kv_xs = None if kv is None else kv.layers
    (x, aux), kv_layers = jax.lax.scan(
        _remat(body, remat_policy), (x, jnp.float32(0.0)),
        (params["blocks"], kv_xs), unroll=Lyr.scan_unroll())
    new_kv = None if kv is None else kv._replace(layers=kv_layers)
    return x, aux, Caches(kv=new_kv)


def hybrid_stack(cfg, params, x, positions, mode, caches: Caches,
                 remat_policy="full"):
    """recurrentgemma: scan over (rec, rec, attn) triples + rec tail."""
    kv = caches.kv
    ntri = cfg.num_layers // cfg.attn_period
    tail = cfg.num_layers - ntri * cfg.attn_period
    page_table = None if kv is None else kv.page_table
    seq_lens = None if kv is None else kv.seq_lens

    def triple_body(carry, inp):
        x = carry
        x = constrain(x, "batch", "seq", "act_embed")
        p_t, kv_l, (h1, c1), (h2, c2) = inp
        cache1 = (h1, c1) if mode == "decode" else None
        cache2 = (h2, c2) if mode == "decode" else None
        x, nc1 = rec_block(cfg, p_t["rec1"], x, mode, cache1)
        x, nc2 = rec_block(cfg, p_t["rec2"], x, mode, cache2)
        h = Lyr.apply_norm(cfg, p_t["attn"]["norm1"], x)
        a, kv_l = _attn_mix(cfg, p_t["attn"]["attn"], h, positions, mode,
                            kv_l, page_table, seq_lens, cfg.local_window)
        x = x + a
        h = Lyr.apply_norm(cfg, p_t["attn"]["norm2"], x)
        x = x + Lyr.apply_mlp(cfg, p_t["attn"]["ffn"], h)
        return x, (kv_l, nc1, nc2)

    def _dummy_rec(n):
        return (jnp.zeros((n, 1)), jnp.zeros((n, 1)))

    hs, cs = [], []
    if ntri > 0:
        rec_xs = ((caches.ssm_h[:ntri], caches.ssm_conv[:ntri]),
                  (caches.ssm_h[ntri:2 * ntri],
                   caches.ssm_conv[ntri:2 * ntri])
                  ) if mode == "decode" else (_dummy_rec(ntri),
                                              _dummy_rec(ntri))
        kv_xs = None if kv is None else kv.layers
        x, (kv_layers, nc1, nc2) = jax.lax.scan(
            _remat(triple_body, remat_policy), x,
            (params["triples"], kv_xs, rec_xs[0], rec_xs[1]),
            unroll=Lyr.scan_unroll())
        hs, cs = [nc1[0], nc2[0]], [nc1[1], nc2[1]]
    else:  # probe configs: tail-only stacks (no attention layers)
        kv_layers = None if kv is None else kv.layers
    if tail:
        def tail_body(carry, inp):
            x = carry
            p_l, (h_l, c_l) = inp
            cache = (h_l, c_l) if mode == "decode" else None
            x, nc = rec_block(cfg, p_l, x, mode, cache)
            return x, nc
        t_xs = ((caches.ssm_h[2 * ntri:], caches.ssm_conv[2 * ntri:])
                if mode == "decode" else _dummy_rec(tail))
        x, nct = jax.lax.scan(_remat(tail_body, remat_policy), x,
                              (params["tail"], t_xs),
                              unroll=Lyr.scan_unroll())
        hs.append(nct[0])
        cs.append(nct[1])

    new_kv = None if kv is None else kv._replace(layers=kv_layers)
    new = Caches(kv=new_kv, ssm_h=jnp.concatenate(hs, 0),
                 ssm_conv=jnp.concatenate(cs, 0))
    return x, jnp.float32(0.0), new


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def embed(cfg, params, tokens, extra_embeds=None, dtype=jnp.bfloat16):
    x = params["embed"].astype(dtype)[tokens]
    if extra_embeds is not None:
        x = x + extra_embeds.astype(dtype)
    return constrain(x, "batch", "seq", "act_embed")


def unembed(cfg, params, x):
    h = Lyr.apply_norm(cfg, params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(h.dtype)
    logits = constrain((h @ w).astype(jnp.float32),
                       "batch", "seq", "vocab")
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward(cfg: ModelConfig, params, tokens, positions=None,
            extra_embeds=None, mode="train", caches: Caches = Caches(),
            remat_policy="full", dtype=jnp.bfloat16,
            return_hidden: bool = False):
    """Returns (logits, aux_loss, new_caches) — or final-normed hidden
    states instead of logits when ``return_hidden`` (the chunked-CE
    training path avoids materializing (B, S, vocab) f32 logits)."""
    B, S = tokens.shape
    if positions is None:
        if mode == "decode":
            positions = caches.kv.seq_lens[:, None] if caches.kv is not None \
                else jnp.zeros((B, 1), jnp.int32)
        else:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    elif positions.ndim == 3 and positions.shape[1] == 3:
        # batch convention: M-RoPE positions arrive batch-first (B, 3, S)
        # so microbatch splitting is uniform; rope wants (3, B, S).
        positions = positions.transpose(1, 0, 2)
    x = embed(cfg, params, tokens, extra_embeds, dtype)
    stack = hybrid_stack if cfg.family == "hybrid" else uniform_stack
    x, aux, new_caches = stack(cfg, params, x, positions, mode, caches,
                               remat_policy)
    if mode == "prefill":
        # only the last position's logits are consumed — unembedding all
        # S positions at 32k×(vocab) dominates prefill compute otherwise
        x = x[:, -1:]
    if return_hidden:
        return Lyr.apply_norm(cfg, params["final_norm"], x), aux, new_caches
    return unembed(cfg, params, x), aux, new_caches
