"""Public model facade: build_model(cfg) → Model.

Uniform API over all ten assigned architectures:

    m = build_model(get_arch("mixtral-8x7b"))
    params = m.init(key)
    loss, metrics = m.loss(params, batch)
    caches = m.make_decode_caches(batch=8, max_seq=1024)
    logits, caches = m.prefill(params, batch, caches)
    logits, caches = m.decode_step(params, tokens, caches)

Batches are dicts: LM families use {tokens, targets[, mm_embeds,
positions]}; enc-dec uses {src_embeds, tokens, targets}.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import params as Prm
from repro.models import ssm as Ssm
from repro.models import transformer as TF
from repro.paged import kv_cache as KV
from repro.parallel.sharding import constrain


def _chunked_ce(cfg, params, h, targets, chunk: int = 512):
    """Cross-entropy over sequence chunks with vocab-sharded logits.

    Materializing (B, S, V) f32 logits dominates big-vocab training
    memory (2.3 GiB/chip on qwen1.5-32b×train_4k) and leaves the (D, V)
    head-gradient partial unsharded; chunking bounds live logits to
    (B, chunk, V/TP) and keeps the W-grad partial vocab-sharded."""
    B, S, D = h.shape
    # one reshard off the model axis (SP) before the chunk loop: slicing
    # a seq-sharded operand per chunk makes GSPMD re-gather h for every
    # chunk in fwd+bwd (observed 4.7e11 B/dev on qwen1.5×train_4k).
    h = constrain(h, "batch", None, None)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(h.dtype)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)),
                          constant_values=-1)
    nb = (S + pad) // chunk
    hb = h.reshape(B, nb, chunk, D).swapaxes(0, 1)
    tb = targets.reshape(B, nb, chunk).swapaxes(0, 1)

    def body(carry, inp):
        ce_sum, n_sum = carry
        hc, tc = inp
        logits = constrain((hc @ w).astype(jnp.float32),
                           "batch", None, "vocab")
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(
                logits / cfg.logit_softcap)
        mask = (tc >= 0).astype(jnp.float32)
        labels = jnp.maximum(tc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (ce_sum + ((logz - gold) * mask).sum(),
                n_sum + mask.sum()), None

    from repro.models.layers import scan_unroll
    (ce_sum, n_sum), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.float32(0.0), jnp.float32(0.0)), (hb, tb),
        unroll=scan_unroll())
    return ce_sum / jnp.maximum(n_sum, 1), n_sum


def kv_dtype_for(cfg: ModelConfig, seq_len: int, batch: int):
    """int8 KV pages when bf16 would blow the v5e HBM budget
    (qwen1.5-32b @ decode_32k — see DESIGN.md §Arch-applicability)."""
    hd = cfg.head_dim_
    layers = TF.num_attn_layers(cfg) + (cfg.num_layers if cfg.is_encdec
                                        else 0)
    bytes_bf16 = 2 * layers * batch * seq_len * cfg.num_kv_heads * hd * 2
    return jnp.int8 if bytes_bf16 > 2.5e12 else jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- params ------------------------------------------------------------
    def specs(self):
        if self.cfg.is_encdec:
            return ED.encdec_specs(self.cfg)
        return TF.lm_specs(self.cfg)

    def init(self, key):
        return Prm.materialize(self.specs(), key)

    def abstract_params(self):
        return Prm.abstract(self.specs())

    def logical_axes(self):
        return Prm.logical_axes(self.specs())

    # ---- training ----------------------------------------------------------
    def loss(self, params, batch, remat_policy: str = "full",
             dtype=jnp.bfloat16):
        cfg = self.cfg
        targets = batch["targets"]
        if cfg.is_encdec:
            enc = ED.encode(cfg, params, batch["src_embeds"],
                            remat_policy, dtype)
            caches = ED.EncDecCaches(None, None, None, None)
            h, _ = ED.decode_stack(cfg, params, batch["tokens"], enc,
                                   "train", caches, remat_policy, dtype,
                                   return_hidden=True)
            aux = jnp.float32(0.0)
        else:
            h, aux, _ = TF.forward(
                cfg, params, batch["tokens"],
                positions=batch.get("positions"),
                extra_embeds=batch.get("mm_embeds"),
                mode="train", remat_policy=remat_policy, dtype=dtype,
                return_hidden=True)
        ce, n_tok = _chunked_ce(cfg, params, h, targets)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n_tok}

    # ---- serving -----------------------------------------------------------
    def make_decode_caches(self, batch: int, max_seq: int,
                           kv_dtype=None, num_pages: Optional[int] = None,
                           abstract: bool = False,
                           window_ring: bool = False):
        """``window_ring``: windowed archs (SWA / hybrid local attn) get
        ring page tables bounded by the window — pages recycle through
        the allocator instead of growing with the sequence."""
        cfg = self.cfg
        page = KV.PAGE_SIZE
        pps = -(-max_seq // page)
        if window_ring:
            window = (cfg.sliding_window
                      or (cfg.local_window if cfg.family == "hybrid"
                          else None))
            if window:
                pps = min(pps, window // page + 2)
        kv_dtype = kv_dtype or kv_dtype_for(cfg, max_seq, batch)
        mk = KV.abstract_paged_kv if abstract else KV.init_paged_kv

        def paged(n_layers):
            np_total = num_pages or batch * pps
            return mk(n_layers, np_total, batch, pps, cfg.num_kv_heads,
                      cfg.head_dim_, kv_dtype, page)

        if cfg.is_encdec:
            return ED.EncDecCaches(
                self_kv=paged(cfg.num_layers),
                cross_k=None, cross_v=None, enc_valid=None)

        n_attn = TF.num_attn_layers(cfg)
        n_rec = TF.num_rec_layers(cfg)
        kv = paged(n_attn) if n_attn else None
        ssm_h = ssm_conv = None
        if n_rec:
            if cfg.family == "ssm":
                h_shape = (n_rec, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                           cfg.ssm_state)
                c_shape = (n_rec, batch, cfg.ssm_conv - 1,
                           Ssm.conv_dim(cfg))
            else:  # hybrid RG-LRU
                r = cfg.lru_width or cfg.d_model
                h_shape = (n_rec, batch, r)
                c_shape = (n_rec, batch, 3, r)
            if abstract:
                ssm_h = jax.ShapeDtypeStruct(h_shape, jnp.float32)
                ssm_conv = jax.ShapeDtypeStruct(c_shape, jnp.bfloat16)
            else:
                ssm_h = jnp.zeros(h_shape, jnp.float32)
                ssm_conv = jnp.zeros(c_shape, jnp.bfloat16)
        return TF.Caches(kv=kv, ssm_h=ssm_h, ssm_conv=ssm_conv)

    def prefill(self, params, batch, caches, remat_policy: str = "full",
                dtype=jnp.bfloat16):
        """Full-sequence pass that populates the decode caches.
        Returns (last-position logits, caches ready for decode_step)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        S = tokens.shape[1]
        if cfg.is_encdec:
            enc = ED.encode(cfg, params, batch["src_embeds"],
                            remat_policy, dtype)
            caches = caches._replace(
                enc_valid=batch.get("src_valid"))
            logits, caches = ED.decode_stack(
                cfg, params, tokens, enc, "prefill", caches,
                remat_policy, dtype)
            kv = caches.self_kv._replace(
                seq_lens=caches.self_kv.seq_lens + S)
            return logits[:, -1], caches._replace(self_kv=kv)
        logits, _, new = TF.forward(
            cfg, params, tokens, positions=batch.get("positions"),
            extra_embeds=batch.get("mm_embeds"), mode="prefill",
            caches=caches, remat_policy=remat_policy, dtype=dtype)
        if new.kv is not None:
            new = new._replace(kv=new.kv._replace(
                seq_lens=new.kv.seq_lens + S))
        return logits[:, -1], new

    def decode_step(self, params, tokens, caches, dtype=jnp.bfloat16):
        """One token per sequence.  tokens: (B, 1).  Returns (logits
        (B, vocab), caches with seq_lens advanced)."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, new = ED.decode_stack(
                cfg, params, tokens, None, "decode", caches,
                remat_policy="none", dtype=dtype)
            kv = new.self_kv._replace(seq_lens=new.self_kv.seq_lens + 1)
            return logits[:, 0], new._replace(self_kv=kv)
        logits, _, new = TF.forward(
            cfg, params, tokens, mode="decode", caches=caches,
            remat_policy="none", dtype=dtype)
        if new.kv is not None:
            new = new._replace(kv=new.kv._replace(
                seq_lens=new.kv.seq_lens + 1))
        elif caches.kv is None and cfg.family == "ssm":
            pass  # ssm caches carry no seq_lens
        return logits[:, 0], new


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
