"""Unified Ouroboros allocator facade — the six paper variants.

Variant ids match the paper's driver programs (§3):

    page      — plain ring queues of pages          (fig. 1)
    chunk     — plain ring queues of chunks+bitmaps (fig. 2)
    va_page   — virtualized array queue of pages    (fig. 3)
    vl_page   — virtualized list queue of pages     (fig. 4)
    va_chunk  — virtualized array queue of chunks   (fig. 5)
    vl_chunk  — virtualized list queue of chunks    (fig. 6)

Public API (all jit-safe, functional):

    ouro = Ouroboros(cfg, "va_page", backend="pallas")
    state = ouro.init()                              # core.arena.Arena
    state, offs = ouro.alloc(state, sizes_bytes, mask)   # offs in words, -1 = fail
    state = ouro.free(state, offs, sizes_bytes, mask)
    heap  = write_pattern(state, offs, sizes_bytes, tag) # benchmark helpers
    ok    = check_pattern(state, offs, sizes_bytes, tag)

State is the flat device-resident **arena** (core/arena.py): one int32
word image ``state.mem`` (heap + pool ring + class queue ring or
segment directory + chunk bitmaps, at fixed offsets) plus one int32
control block ``state.ctl`` (every counter).  ``backend`` selects the
transaction implementation: ``"jnp"`` (default) is the pure-XLA
reference path, ``"pallas"`` executes each whole transaction —
including the va/vl segment walk — as ONE fused ``pallas_call``
(kernels/alloc_txn.arena_*_txn; interpret mode on CPU).  Both backends
are bit-identical — the jnp path is the oracle for
tests/test_alloc_txn_parity.py — and share ``init`` state, so a heap
can switch backends mid-stream (also asserted there).

With ``num_shards > 1`` the heap is partitioned into that many
independent arenas (core/shards.py, DESIGN.md §9): state becomes a
``shards.ShardedArena`` of stacked per-shard slabs, requests route to
a home shard (hashed, or caller-hinted) with a bounded overflow walk
across neighbors on exhaustion, and each transaction is STILL one
``pallas_call`` — the kernels grid the (attempt, shard) schedule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import arena, defrag as _defrag, shards, transactions
from repro.core.heap import HeapConfig

VARIANTS = ("page", "chunk", "va_page", "vl_page", "va_chunk", "vl_chunk")
BACKENDS = ("jnp", "pallas")
LOWERINGS = ("auto", "whole", "blocked")


def _split(variant: str):
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; pick from {VARIANTS}")
    if variant in ("page", "chunk"):
        return variant, "ring"
    fam, kind = variant.split("_")
    return kind, fam


@dataclasses.dataclass(frozen=True)
class Ouroboros:
    """Facade binding a HeapConfig to one of the six paper variants.

    ``backend`` picks the transaction implementation (jnp reference
    path vs fused Pallas kernels) and — for the Pallas backend —
    ``lowering`` the kernel shape: ``"whole"`` (full-arena refs),
    ``"blocked"`` (the region-blocked compiled lowering, DESIGN.md
    §8), or ``"auto"`` (kernels/ops picks per platform /
    REPRO_ALLOC_LOWERING).  Both lowerings are bit-identical to the
    jnp oracle and to each other (tests/test_alloc_txn_parity).

    ``num_shards > 1`` partitions the heap into independent arenas
    with overflow routing (core/shards.py, DESIGN.md §9);
    ``overflow_walk`` bounds how many neighbor shards a request may
    retry after its home shard fails (``None`` = all of them).

    Basic usage (every returned offset is a heap word offset; −1
    marks a failed lane, the GPU original's nullptr):

    >>> import jax.numpy as jnp
    >>> from repro.core import HeapConfig, Ouroboros
    >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
    ...                  min_page_bytes=16)
    >>> ouro = Ouroboros(cfg, "page")
    >>> state = ouro.init()
    >>> sizes = jnp.full(4, 64, jnp.int32)      # four 64 B requests
    >>> mask = jnp.ones(4, bool)
    >>> state, offs = ouro.alloc(state, sizes, mask)
    >>> bool((offs >= 0).all())                 # all granted
    True
    >>> sorted({int(o) % 16 for o in offs})     # 64 B = 16-word aligned
    [0]
    >>> state = ouro.free(state, offs, sizes, mask)

    Sharded, with a caller-pinned home shard (the offset's owning
    shard is its global offset divided by the per-shard heap words):

    >>> ouro4 = Ouroboros(cfg, "page", num_shards=4)
    >>> st = ouro4.init()
    >>> st, offs = ouro4.alloc(st, sizes, mask, shard_hint=2)
    >>> [int(o) // ouro4.layout.shard_words for o in offs]
    [2, 2, 2, 2]
    """
    cfg: HeapConfig
    variant: str
    backend: str = "jnp"
    lowering: str = "auto"
    num_shards: int = 1
    overflow_walk: Optional[int] = None

    def __post_init__(self):
        _split(self.variant)
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; pick from {BACKENDS}")
        if self.lowering not in LOWERINGS:
            raise ValueError(
                f"unknown lowering {self.lowering!r}; pick from "
                f"{LOWERINGS}")
        if self.num_shards != 1:
            # validates divisibility + per-shard layout viability early
            shards.layout(self.cfg, self.num_shards, self.kind,
                          self.family)
            shards.resolve_walk(self.num_shards, self.overflow_walk)
        elif self.overflow_walk is not None:
            # an ignored knob is a lie: without shards there is
            # nothing to walk, so say so (symmetric with shard_hint)
            raise ValueError("overflow_walk requires num_shards > 1")

    @property
    def kind(self) -> str:
        return _split(self.variant)[0]

    @property
    def family(self) -> str:
        return _split(self.variant)[1]

    @property
    def walk(self) -> int:
        """Resolved overflow-walk length (0 when unsharded)."""
        if self.num_shards == 1:
            return 0
        return shards.resolve_walk(self.num_shards, self.overflow_walk)

    @property
    def layout(self):
        """The static word layout: an ``arena.ArenaLayout`` for a
        single arena, a ``shards.ShardLayout`` when sharded."""
        if self.num_shards == 1:
            return arena.layout(self.cfg, self.kind, self.family)
        return shards.layout(self.cfg, self.num_shards, self.kind,
                             self.family)

    def init(self):
        """Fresh allocator state (``arena.Arena``, or
        ``shards.ShardedArena`` when ``num_shards > 1``).  Backend-,
        lowering-, and routing-free: a live heap can switch any of
        them mid-stream."""
        return transactions.init(self.cfg, self.kind, self.family,
                                 self.num_shards)

    # -- transactions -------------------------------------------------------

    def alloc(self, state, sizes_bytes, mask, shard_hint=None):
        """One bulk allocation transaction.

        Returns ``(state', word_offsets)``; offset −1 marks a failed
        lane (over-large size / exhausted inventory).  ``shard_hint``
        (sharded only): ``None`` routes each lane by hash, an int or a
        per-lane int32 array pins home shards — a static int with
        ``overflow_walk=0`` additionally takes the pinned fast path,
        where the other shards bypass the kernel entirely (the shard
        analogue of ``Region.blocking == "untouched"``)."""
        if self.num_shards == 1:
            if shard_hint is not None:
                raise ValueError("shard_hint requires num_shards > 1")
            return self._alloc(state, sizes_bytes, mask)
        pinned = shards.static_hint(shard_hint)
        if pinned is not None and self.walk == 0:
            return self._alloc_pinned(state, sizes_bytes, mask,
                                      pinned % self.num_shards)
        home = shards.home_shards(sizes_bytes.shape[0], self.num_shards,
                                  shard_hint)
        return self._alloc_sharded(state, sizes_bytes, mask, home)

    def free(self, state, offsets_words, sizes_bytes, mask,
             shard_hint=None):
        """One bulk free transaction (offsets as returned by
        ``alloc``; sharded offsets are global, each owned by exactly
        one shard).  A static int ``shard_hint`` with
        ``overflow_walk=0`` frees on that shard alone (lanes whose
        offsets live elsewhere are dropped — the pinned contract)."""
        if self.num_shards == 1:
            if shard_hint is not None:
                raise ValueError("shard_hint requires num_shards > 1")
            return self._free(state, offsets_words, sizes_bytes, mask)
        pinned = shards.static_hint(shard_hint)
        if pinned is not None and self.walk == 0:
            return self._free_pinned(state, offsets_words, sizes_bytes,
                                     mask, pinned % self.num_shards)
        return self._free_sharded(state, offsets_words, sizes_bytes,
                                  mask)

    def grow(self, state, need, size_bytes: int, lanes: int, home=None):
        """Grow-to-target-lens transaction: the decode mega-step entry.

        ``need`` is a DEVICE per-slot page-need vector ``(B,)`` (how
        many new ``size_bytes`` regions each slot must be granted) —
        no host slot list, so the whole call is jit-traceable inside a
        fused decode tick.  Lane routing is
        :func:`transactions.grow_lanes` (slot-major, the same order
        the host loop issued); the bulk grant itself is the ordinary
        single transaction — still ONE ``pallas_call`` under
        ``backend="pallas"`` with either lowering, sharded or not.
        ``home`` (sharded only) gives per-SLOT home shards ``(B,)``;
        ``None`` homes slot ``b`` on ``b % num_shards``, the KV
        cache's routing.

        Returns ``(state', lane_offsets, lane_slot, lane_rank,
        lane_mask)`` — offset −1 marks a failed or masked lane.
        Deliberately NOT jitted here: callers embed it in their own
        jitted step (the engine donates the whole carry).

        >>> import jax.numpy as jnp
        >>> from repro.core import HeapConfig, Ouroboros
        >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
        ...                  min_page_bytes=16)
        >>> ouro = Ouroboros(cfg, "page")
        >>> st = ouro.init()
        >>> need = jnp.array([2, 0, 1], jnp.int32)
        >>> st, offs, slot, rank, mask = ouro.grow(st, need, 64, lanes=4)
        >>> slot.tolist(), mask.tolist()
        ([0, 0, 2, 2], [True, True, True, False])
        >>> bool((offs[:3] >= 0).all()), int(offs[3])
        (True, -1)
        """
        lane_slot, lane_rank, lane_mask = transactions.grow_lanes(
            need, lanes)
        sizes = jnp.full(lanes, size_bytes, jnp.int32)
        if self.num_shards == 1:
            if home is not None:
                raise ValueError("home requires num_shards > 1")
            state, offs = transactions.alloc(
                self.cfg, self.kind, self.family, state, sizes,
                lane_mask, self.backend, self.lowering)
        else:
            if home is None:
                home = jnp.arange(need.shape[0], dtype=jnp.int32)
            lane_home = (jnp.asarray(home, jnp.int32)
                         % self.num_shards)[lane_slot]
            state, offs = transactions.sharded_alloc(
                self.cfg, self.num_shards, self.kind, self.family,
                state, sizes, lane_mask, lane_home, self.walk,
                self.backend, self.lowering)
        return state, offs, lane_slot, lane_rank, lane_mask

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _alloc(self, state, sizes_bytes, mask):
        return transactions.alloc(self.cfg, self.kind, self.family,
                                  state, sizes_bytes, mask, self.backend,
                                  self.lowering)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _free(self, state, offsets_words, sizes_bytes, mask):
        return transactions.free(self.cfg, self.kind, self.family, state,
                                 offsets_words, sizes_bytes, mask,
                                 self.backend, self.lowering)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _alloc_sharded(self, state, sizes_bytes, mask, home):
        return transactions.sharded_alloc(
            self.cfg, self.num_shards, self.kind, self.family, state,
            sizes_bytes, mask, home, self.walk, self.backend,
            self.lowering)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _free_sharded(self, state, offsets_words, sizes_bytes, mask):
        return transactions.sharded_free(
            self.cfg, self.num_shards, self.kind, self.family, state,
            offsets_words, sizes_bytes, mask, self.backend,
            self.lowering)

    @functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=1)
    def _alloc_pinned(self, state, sizes_bytes, mask, s):
        """Static-hint fast path: the transaction runs the SINGLE-arena
        kernel on shard ``s``'s slab; the other shards never enter the
        kernel (static slices around it)."""
        scfg = shards.shard_config(self.cfg, self.num_shards)
        sub, local = transactions.alloc(
            scfg, self.kind, self.family, shards.take_shard(state, s),
            sizes_bytes, mask, self.backend, self.lowering)
        offs = jnp.where(local >= 0, s * scfg.total_words + local, local)
        return shards.with_shard(state, s, sub), offs

    @functools.partial(jax.jit, static_argnums=(0, 5), donate_argnums=1)
    def _free_pinned(self, state, offsets_words, sizes_bytes, mask, s):
        scfg = shards.shard_config(self.cfg, self.num_shards)
        Ws = scfg.total_words
        sel = mask & (offsets_words >= s * Ws) \
            & (offsets_words < (s + 1) * Ws)
        local = jnp.where(sel, offsets_words - s * Ws, -1)
        sub = transactions.free(
            scfg, self.kind, self.family, shards.take_shard(state, s),
            local, sizes_bytes, sel, self.backend, self.lowering)
        return shards.with_shard(state, s, sub)

    def compact(self, state):
        if self.num_shards == 1:
            return transactions.compact(self.cfg, self.kind, self.family,
                                        state)
        return transactions.sharded_compact(
            self.cfg, self.num_shards, self.kind, self.family, state)

    # -- defragmentation (core/defrag.py, DESIGN.md §10) --------------------

    def _moves(self, max_moves) -> int:
        if max_moves is None:
            max_moves = min(_defrag.DEFAULT_MAX_MOVES,
                            self.cfg.num_chunks
                            * self.cfg.max_pages_per_chunk)
        if not isinstance(max_moves, int) or max_moves < 1:
            raise ValueError(
                f"max_moves must be a positive int, got {max_moves!r}")
        return max_moves

    def defrag(self, state, max_moves=None):
        """One defragmentation wave: plan (pure jnp — pick live extents
        in the sparsest chunks, assign dense destinations), then execute
        the migration as ONE fused transaction under the configured
        backend/lowering (bit-identical across all of them).  Returns
        ``(state', forwarding)`` where ``forwarding`` is the old→new
        :class:`~repro.core.defrag.Forwarding` table callers use to
        remap held offsets (``defrag.forward_offsets``, the KV cache's
        ``apply_forwarding``).  Chunk kinds only; for page kinds the
        wave is a no-op with an empty table.  Sharded arenas defragment
        every shard in the same single wave; cross-shard moves are
        :meth:`rebalance`'s job.

        >>> import jax.numpy as jnp
        >>> from repro.core import HeapConfig, Ouroboros
        >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
        ...                  min_page_bytes=16)
        >>> ouro = Ouroboros(cfg, "vl_chunk")
        >>> st = ouro.init()
        >>> sizes = jnp.full(8, 16, jnp.int32)
        >>> ones = jnp.ones(8, bool)
        >>> st, offs = ouro.alloc(st, sizes, ones)     # one dense chunk
        >>> st, fwd = ouro.defrag(st)
        >>> int((fwd.src >= 0).sum())                  # nothing to move
        0
        >>> st, offs2 = ouro.alloc(st, sizes, ones)    # heap still serves
        >>> bool((offs2 >= 0).all())
        True
        """
        M = self._moves(max_moves)
        if self.kind != "chunk":
            return state, _defrag.empty_forwarding(M)
        if self.num_shards == 1:
            return self._defrag(state, M)
        return self._defrag_sharded(state, M)

    def rebalance(self, state, max_moves=None):
        """One cross-shard rebalance wave (sharded arenas only): plan
        moves from the most- to the least-loaded shard
        (``shards.rebalance_plan_math``) and execute them through the
        same single-kernel migration wave as :meth:`defrag`.  Returns
        ``(state', forwarding)`` with GLOBAL offsets."""
        if self.num_shards == 1:
            raise ValueError("rebalance requires num_shards > 1")
        M = self._moves(max_moves)
        if self.kind != "chunk":
            return state, _defrag.empty_forwarding(M)
        return self._rebalance(state, M)

    @functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=1)
    def _defrag(self, state, max_moves):
        src, dst, sizes = transactions.defrag_plan(
            self.cfg, self.kind, self.family, state, max_moves)
        st = transactions.migrate(self.cfg, self.kind, self.family,
                                  state, src, dst, sizes, self.backend,
                                  self.lowering)
        return st, _defrag.Forwarding(src=src, dst=dst, sizes=sizes)

    @functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=1)
    def _defrag_sharded(self, state, max_moves):
        src, dst, sizes = transactions.sharded_defrag_plan(
            self.cfg, self.num_shards, self.kind, self.family, state,
            max_moves)
        st = transactions.sharded_migrate(
            self.cfg, self.num_shards, self.kind, self.family, state,
            src, dst, sizes, self.backend, self.lowering)
        return st, _defrag.Forwarding(src=src, dst=dst, sizes=sizes)

    @functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=1)
    def _rebalance(self, state, max_moves):
        src, dst, sizes = shards.rebalance_plan_math(
            self.cfg, self.num_shards, self.kind, self.family,
            state.mem, state.ctl, max_moves=max_moves)
        st = transactions.sharded_migrate(
            self.cfg, self.num_shards, self.kind, self.family, state,
            src, dst, sizes, self.backend, self.lowering)
        return st, _defrag.Forwarding(src=src, dst=dst, sizes=sizes)

    # -- fragmentation observability ----------------------------------------

    def frag_stats(self, state):
        """Fragmentation counters of ``state``: a dict with
        ``free_words``, ``largest_free_extent``, and ``frag_ratio``
        (``1 − largest_free/total_free``; 0 = one solid free block).
        Scalars for a single arena, per-shard ``(S,)`` arrays when
        ``num_shards > 1`` — the signal the serving engine surfaces
        and uses to trigger waves."""
        if self.num_shards == 1:
            free, largest = self._frag_stats(state)
        else:
            free, largest = self._frag_stats_sharded(state)
        return {"free_words": free, "largest_free_extent": largest,
                "frag_ratio": _defrag.frag_ratio(free, largest)}

    @functools.partial(jax.jit, static_argnums=0)
    def _frag_stats(self, state):
        return _defrag.frag_stats_math(self.cfg, self.kind, self.family,
                                       state.mem, state.ctl)

    @functools.partial(jax.jit, static_argnums=0)
    def _frag_stats_sharded(self, state):
        scfg = shards.shard_config(self.cfg, self.num_shards)
        pairs = [_defrag.frag_stats_math(scfg, self.kind, self.family,
                                         state.mem[s], state.ctl[s])
                 for s in range(self.num_shards)]
        return (jnp.stack([p[0] for p in pairs]),
                jnp.stack([p[1] for p in pairs]))

    def heap(self, state):
        """The heap proper (the paper's word array): for sharded state
        the per-shard heap regions concatenated in shard order, so
        GLOBAL word offsets index it directly."""
        if self.num_shards == 1:
            return arena.heap_of(self.layout, state)
        return shards.heap_of(self.layout, state)

    def _with_heap(self, state, heap):
        if self.num_shards == 1:
            return arena.with_heap(self.layout, state, heap)
        return shards.with_heap(self.layout, state, heap)

    # -- benchmark data path (paper §3: "writing some data, checking that
    #    the data is correct when read back") -------------------------------
    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def write_pattern(self, state, offsets_words, sizes_bytes, tag):
        heap = write_words(self.cfg, self.heap(state), offsets_words,
                           sizes_bytes, tag)
        return self._with_heap(state, heap)

    @functools.partial(jax.jit, static_argnums=0)
    def check_pattern(self, state, offsets_words, sizes_bytes, tag):
        return check_words(self.cfg, self.heap(state), offsets_words,
                           sizes_bytes, tag)


def _word_grid(cfg: HeapConfig, offsets_words, sizes_bytes):
    n = offsets_words.shape[0]
    maxw = cfg.words_per_chunk  # largest page
    nw = jnp.maximum(sizes_bytes // 4, 1).astype(jnp.int32)
    j = jnp.arange(maxw, dtype=jnp.int32)[None, :]
    ok = (j < nw[:, None]) & (offsets_words[:, None] >= 0)
    words = offsets_words[:, None] + j
    return words, ok


def write_words(cfg, heap, offsets_words, sizes_bytes, tag):
    """Fill each allocation with ``tag[i]`` (one distinct word per alloc).

    ``heap`` must be the heap *view* (``cfg.total_words`` long), never
    the whole arena image: dropped lanes index one-past-the-end."""
    words, ok = _word_grid(cfg, offsets_words, sizes_bytes)
    vals = jnp.broadcast_to(tag[:, None], words.shape)
    return heap.at[jnp.where(ok, words, heap.shape[0])].set(
        vals, mode="drop")


def check_words(cfg, heap, offsets_words, sizes_bytes, tag):
    """Per-allocation bool: every word still holds its tag (detects
    overlapping allocations — the paper's correctness check)."""
    words, ok = _word_grid(cfg, offsets_words, sizes_bytes)
    got = heap.at[words].get(mode="fill", fill_value=-1)
    good = jnp.where(ok, got == tag[:, None], True)
    return good.all(axis=1) & (offsets_words >= 0)
