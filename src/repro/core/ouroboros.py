"""Unified Ouroboros allocator facade — the six paper variants.

Variant ids match the paper's driver programs (§3):

    page      — plain ring queues of pages          (fig. 1)
    chunk     — plain ring queues of chunks+bitmaps (fig. 2)
    va_page   — virtualized array queue of pages    (fig. 3)
    vl_page   — virtualized list queue of pages     (fig. 4)
    va_chunk  — virtualized array queue of chunks   (fig. 5)
    vl_chunk  — virtualized list queue of chunks    (fig. 6)

Public API (all jit-safe, functional):

    ouro = Ouroboros(cfg, "va_page", backend="pallas")
    state = ouro.init()                              # core.arena.Arena
    state, offs = ouro.alloc(state, sizes_bytes, mask)   # offs in words, -1 = fail
    state = ouro.free(state, offs, sizes_bytes, mask)
    heap  = write_pattern(state, offs, sizes_bytes, tag) # benchmark helpers
    ok    = check_pattern(state, offs, sizes_bytes, tag)

State is the flat device-resident **arena** (core/arena.py): one int32
word image ``state.mem`` (heap + pool ring + class queue ring or
segment directory + chunk bitmaps, at fixed offsets) plus one int32
control block ``state.ctl`` (every counter).  ``backend`` selects the
transaction implementation: ``"jnp"`` (default) is the pure-XLA
reference path, ``"pallas"`` executes each whole transaction —
including the va/vl segment walk — as ONE fused ``pallas_call``
(kernels/alloc_txn.arena_*_txn; interpret mode on CPU).  Both backends
are bit-identical — the jnp path is the oracle for
tests/test_alloc_txn_parity.py — and share ``init`` state, so a heap
can switch backends mid-stream (also asserted there).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import arena, transactions
from repro.core.heap import HeapConfig

VARIANTS = ("page", "chunk", "va_page", "vl_page", "va_chunk", "vl_chunk")
BACKENDS = ("jnp", "pallas")
LOWERINGS = ("auto", "whole", "blocked")


def _split(variant: str):
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; pick from {VARIANTS}")
    if variant in ("page", "chunk"):
        return variant, "ring"
    fam, kind = variant.split("_")
    return kind, fam


@dataclasses.dataclass(frozen=True)
class Ouroboros:
    """Facade binding a HeapConfig to one of the six variants, a
    transaction backend (jnp reference path or fused Pallas kernels),
    and — for the Pallas backend — a kernel ``lowering``: ``"whole"``
    (full-arena refs), ``"blocked"`` (the region-blocked compiled
    lowering, DESIGN.md §8), or ``"auto"`` (kernels/ops picks per
    platform / REPRO_ALLOC_LOWERING).  Both lowerings are bit-identical
    to the jnp oracle and to each other (tests/test_alloc_txn_parity)."""
    cfg: HeapConfig
    variant: str
    backend: str = "jnp"
    lowering: str = "auto"

    def __post_init__(self):
        _split(self.variant)
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; pick from {BACKENDS}")
        if self.lowering not in LOWERINGS:
            raise ValueError(
                f"unknown lowering {self.lowering!r}; pick from "
                f"{LOWERINGS}")

    @property
    def kind(self) -> str:
        return _split(self.variant)[0]

    @property
    def family(self) -> str:
        return _split(self.variant)[1]

    @property
    def layout(self) -> arena.ArenaLayout:
        """The static word layout of this variant's arena."""
        return arena.layout(self.cfg, self.kind, self.family)

    def init(self) -> arena.Arena:
        return transactions.init(self.cfg, self.kind, self.family)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def alloc(self, state, sizes_bytes, mask):
        return transactions.alloc(self.cfg, self.kind, self.family,
                                  state, sizes_bytes, mask, self.backend,
                                  self.lowering)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def free(self, state, offsets_words, sizes_bytes, mask):
        return transactions.free(self.cfg, self.kind, self.family, state,
                                 offsets_words, sizes_bytes, mask,
                                 self.backend, self.lowering)

    def compact(self, state):
        return transactions.compact(self.cfg, self.kind, self.family,
                                    state)

    def heap(self, state: arena.Arena):
        """The heap proper (the paper's word array) inside the arena."""
        return arena.heap_of(self.layout, state)

    # -- benchmark data path (paper §3: "writing some data, checking that
    #    the data is correct when read back") -------------------------------
    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def write_pattern(self, state, offsets_words, sizes_bytes, tag):
        heap = write_words(self.cfg, self.heap(state), offsets_words,
                           sizes_bytes, tag)
        return arena.with_heap(self.layout, state, heap)

    @functools.partial(jax.jit, static_argnums=0)
    def check_pattern(self, state, offsets_words, sizes_bytes, tag):
        return check_words(self.cfg, self.heap(state), offsets_words,
                           sizes_bytes, tag)


def _word_grid(cfg: HeapConfig, offsets_words, sizes_bytes):
    n = offsets_words.shape[0]
    maxw = cfg.words_per_chunk  # largest page
    nw = jnp.maximum(sizes_bytes // 4, 1).astype(jnp.int32)
    j = jnp.arange(maxw, dtype=jnp.int32)[None, :]
    ok = (j < nw[:, None]) & (offsets_words[:, None] >= 0)
    words = offsets_words[:, None] + j
    return words, ok


def write_words(cfg, heap, offsets_words, sizes_bytes, tag):
    """Fill each allocation with ``tag[i]`` (one distinct word per alloc).

    ``heap`` must be the heap *view* (``cfg.total_words`` long), never
    the whole arena image: dropped lanes index one-past-the-end."""
    words, ok = _word_grid(cfg, offsets_words, sizes_bytes)
    vals = jnp.broadcast_to(tag[:, None], words.shape)
    return heap.at[jnp.where(ok, words, heap.shape[0])].set(
        vals, mode="drop")


def check_words(cfg, heap, offsets_words, sizes_bytes, tag):
    """Per-allocation bool: every word still holds its tag (detects
    overlapping allocations — the paper's correctness check)."""
    words, ok = _word_grid(cfg, offsets_words, sizes_bytes)
    got = heap.at[words].get(mode="fill", fill_value=-1)
    good = jnp.where(ok, got == tag[:, None], True)
    return good.all(axis=1) & (offsets_words >= 0)
