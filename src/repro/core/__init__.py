"""Ouroboros-TPU core: dynamic memory management as functional JAX.

The paper's contribution (Standish 2025 / Winter et al. ICS'20) lives
here — see DESIGN.md §1-2 for the GPU→TPU mechanism mapping.
"""
from repro.core.arena import Arena, ArenaLayout
from repro.core.defrag import Forwarding
from repro.core.heap import HeapConfig
from repro.core.ouroboros import BACKENDS, LOWERINGS, Ouroboros, VARIANTS
from repro.core.shards import ShardedArena, ShardLayout

__all__ = ["Arena", "ArenaLayout", "BACKENDS", "Forwarding", "HeapConfig",
           "LOWERINGS", "Ouroboros", "ShardLayout", "ShardedArena",
           "VARIANTS"]
