"""Masked group operations — the TPU transplant of CUDA warp votes.

The paper's central porting difficulty (§2) is that CUDA coalesces
allocations inside a warp with *masked* vote functions
(``__activemask()`` + ``__ballot_sync``), while SYCL group operations
require every work-item of the sub-group to participate — the paper's
emulation deadlocks on NVIDIA backends, and §5 explicitly calls for
"group reduction algorithms to be masked by the active threads only".

On TPU the data-parallel unit is the whole request vector, and a mask is
just another operand — so the wished-for masked group operations exist
natively.  These helpers are the allocator's coalescing machinery:
``masked_rank`` is the lane-aggregated analogue of warp-aggregated
allocation (one queue-counter update per *class*, not per request).
"""
from __future__ import annotations

import jax.numpy as jnp


def masked_ballot(mask):
    """Pack a boolean lane mask into uint32 words, LSB-first.

    The analogue of ``__ballot_sync(__activemask(), pred)``: returns
    ``ceil(N/32)`` words whose bit ``i%32`` of word ``i//32`` is lane
    *i*'s predicate.
    """
    mask = mask.astype(jnp.uint32)
    n = mask.shape[0]
    pad = (-n) % 32
    mask = jnp.pad(mask, (0, pad)).reshape(-1, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (mask * weights[None, :]).sum(axis=1, dtype=jnp.uint32)


def masked_prefix_sum(x, mask):
    """Exclusive prefix sum over active lanes only (inactive lanes: 0)."""
    x = jnp.where(mask, x, 0)
    return jnp.cumsum(x) - x


def masked_rank(cls, mask, num_classes):
    """Rank of each active lane among active lanes of the same class.

    This is warp-aggregated allocation generalized to the request
    vector: lane *i* with class *c* gets rank = number of earlier active
    lanes with the same class.  Returns ``(rank, counts)`` where
    ``counts[c]`` is the total number of active lanes in class ``c`` —
    the single aggregated queue-counter delta per class.
    """
    cls = cls.astype(jnp.int32)
    onehot = (cls[:, None] == jnp.arange(num_classes, dtype=jnp.int32)[None, :])
    onehot = jnp.where(mask[:, None], onehot, False).astype(jnp.int32)
    inc = jnp.cumsum(onehot, axis=0)
    rank = jnp.take_along_axis(inc - onehot, cls[:, None] % num_classes,
                               axis=1)[:, 0]
    counts = inc[-1] if cls.shape[0] > 0 else jnp.zeros(
        num_classes, jnp.int32)
    return jnp.where(mask, rank, 0).astype(jnp.int32), counts.astype(jnp.int32)


def segment_counts(cls, mask, num_classes):
    """Per-class active-lane counts (no ranks needed)."""
    onehot = (cls[:, None] == jnp.arange(num_classes, dtype=jnp.int32)[None, :])
    onehot = jnp.where(mask[:, None], onehot, False)
    return onehot.sum(axis=0, dtype=jnp.int32)
