"""Device-resident arena: the whole allocator state as two flat arrays.

The nested NamedTuple pytrees that PR 1 threaded through every
transaction (``RingState`` / ``VirtState`` / ``AllocCtx`` /
``ChunkMeta``) are now *views*: the state that actually lives on device
— and that ``Ouroboros.init`` returns — is an :class:`Arena` of

    ``mem``  one int32 word image holding, at fixed offsets, the heap
             proper, the free-chunk pool ring, the class queue ring (or
             the virtualized segment directory), and — for chunk
             allocators — the occupancy bitmaps, free counts, and
             chunk→class bindings;
    ``ctl``  one small int32 control block holding every counter:
             per-class ``front``/``back``, the vl ``head``/``tail``
             chunk ids, and the pool's front/back.

Word offsets are static functions of ``(HeapConfig, kind, family)``
computed here (extending the scale-free layout math of ``heap.py``),
so one ``pallas_call`` can execute an entire transaction — including
the va/vl segment walk — against ``mem``/``ctl`` without any host
round trip, and the jnp oracle operates on the *same* layout
(``tests/test_alloc_txn_parity.py`` compares arenas word for word).

The offset table is documented in DESIGN.md §7; ``describe()`` renders
it from the live layout so the doc can never drift silently.

Layouts are pure host math — cheap to inspect:

>>> from repro.core import HeapConfig
>>> from repro.core import arena
>>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
...                  min_page_bytes=16)
>>> lay = arena.layout(cfg, "page", "ring")
>>> lay.region("heap").offset, lay.region("heap").words
(0, 16384)
>>> [r.name for r in lay.regions]
['heap', 'pool_store', 'queue_store']
>>> lay.core_ctl_words == 4 * cfg.num_classes + 2
True
>>> lay.ctl_words == lay.core_ctl_words + lay.tele_words
True
>>> print(lay.describe().splitlines()[1])
  mem[0:16384]  heap (16384,)

The ctl block carries a fixed-offset telemetry region after the core
counters (DESIGN.md §14): per-class alloc/free/failure counts, ring
wraparounds, segment grow/shrink totals, and the overflow-walk depth
histogram.  Both kernel lowerings update it in-place inside the one
transaction ``pallas_call`` and the jnp oracle is its bit-exact
reference (``repro.obs.telemetry`` owns the update math and the host
decoder); transactions that do not account traffic (defrag waves,
compact) carry the words through unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import queues
from repro.core.chunk_alloc import ChunkMeta
from repro.core.heap import HeapConfig

KINDS = ("page", "chunk")
QUEUE_FAMILIES = ("ring", "va", "vl")

# Overflow-walk depth histogram width in the ctl telemetry region:
# bins 0..6 count lanes served at that walk attempt, bin 7 collects
# every deeper attempt (walks are bounded by num_shards - 1 anyway).
TELE_WALK_BINS = 8


class Arena(NamedTuple):
    """The flat device-resident allocator state (see module docstring)."""
    mem: Any  # (layout.mem_words,) int32
    ctl: Any  # (layout.ctl_words,) int32


@dataclasses.dataclass(frozen=True)
class Region:
    """One named window of ``mem``: ``[offset, offset + words)``.

    ``blocking`` records how the region-blocked compiled lowering
    (kernels/alloc_txn_blocked.py) stages this region per grid step:

    - ``"row"``       one (1, shape[1]) row per size-class grid step,
                      selected by the BlockSpec index map;
    - ``"resident"``  the whole region as one VMEM block with a
                      constant index map (fetched once, revisited —
                      Pallas keeps an unchanged block on-chip);
    - ``"hbm"``       the region stays in HBM (``memory_space=ANY``);
                      the kernel DMAs only the touched rows/words
                      through VMEM scratch (heap segments, bitmap rows);
    - ``"untouched"`` the transaction can never write it, so the
                      blocked lowering does not even pass it to the
                      kernel.
    """
    name: str
    offset: int
    shape: Tuple[int, ...]
    blocking: str = "resident"

    @property
    def words(self) -> int:
        return math.prod(self.shape)

    @property
    def end(self) -> int:
        return self.offset + self.words

    @property
    def block_shape(self) -> Optional[Tuple[int, ...]]:
        """VMEM block staged per grid step by the blocked lowering
        (None when the region never enters VMEM wholesale)."""
        if self.blocking == "row":
            return (1,) + self.shape[1:]
        if self.blocking == "resident":
            return self.shape
        return None


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Static word layout of one (cfg, kind, family) arena."""
    cfg: HeapConfig
    kind: str
    family: str
    regions: Tuple[Region, ...]         # contiguous, in mem order
    # ctl block offsets (front/back/head/tail are C words each)
    num_classes: int
    queue_capacity: int                 # ring slots (ring) / items bound
    max_segs: int                       # directory ring width (va/vl)

    @property
    def mem_words(self) -> int:
        return self.regions[-1].end

    @property
    def core_ctl_words(self) -> int:
        """Words the transaction *state* occupies: per-class front/back/
        head/tail plus the pool's front/back.  Everything after them is
        the telemetry region."""
        return 4 * self.num_classes + 2

    @property
    def tele_words(self) -> int:
        return 4 * self.num_classes + 3 + TELE_WALK_BINS

    @property
    def ctl_words(self) -> int:
        return self.core_ctl_words + self.tele_words

    def region(self, name: str) -> Region:
        for r in self.regions:
            if r.name == name:
                return r
        raise KeyError(f"arena({self.kind},{self.family}) has no region "
                       f"{name!r}")

    def has(self, name: str) -> bool:
        return any(r.name == name for r in self.regions)

    # ctl offsets -----------------------------------------------------------
    @property
    def off_front(self) -> int:
        return 0

    @property
    def off_back(self) -> int:
        return self.num_classes

    @property
    def off_head(self) -> int:
        return 2 * self.num_classes

    @property
    def off_tail(self) -> int:
        return 3 * self.num_classes

    @property
    def off_pool_front(self) -> int:
        return 4 * self.num_classes

    @property
    def off_pool_back(self) -> int:
        return 4 * self.num_classes + 1

    # telemetry region (DESIGN.md §14; repro.obs.telemetry owns the
    # update math) — fixed offsets right after the core counters -----------
    @property
    def off_t_alloc(self) -> int:
        return self.core_ctl_words

    @property
    def off_t_free(self) -> int:
        return self.off_t_alloc + self.num_classes

    @property
    def off_t_fail(self) -> int:
        return self.off_t_free + self.num_classes

    @property
    def off_t_wrap(self) -> int:
        return self.off_t_fail + self.num_classes

    @property
    def off_t_grow(self) -> int:
        return self.off_t_wrap + self.num_classes

    @property
    def off_t_shrink(self) -> int:
        return self.off_t_grow + 1

    @property
    def off_t_pool_wrap(self) -> int:
        return self.off_t_shrink + 1

    @property
    def off_t_walk(self) -> int:
        return self.off_t_pool_wrap + 1

    def tele_fields(self) -> Tuple[Tuple[str, int, int], ...]:
        """(name, ctl offset, words) rows of the telemetry region, in
        layout order — the table DESIGN.md §14 and the host decoder
        (obs/telemetry.py) render from."""
        C = self.num_classes
        return (("t_alloc", self.off_t_alloc, C),
                ("t_free", self.off_t_free, C),
                ("t_fail", self.off_t_fail, C),
                ("t_wrap", self.off_t_wrap, C),
                ("t_grow", self.off_t_grow, 1),
                ("t_shrink", self.off_t_shrink, 1),
                ("t_pool_wrap", self.off_t_pool_wrap, 1),
                ("t_walk", self.off_t_walk, TELE_WALK_BINS))

    @property
    def wrap_capacity(self) -> int:
        """Queue positions per full turn of a class queue — the modulus
        the wraparound counter (`t_wrap`) detects crossings of.  Ring
        queues wrap at the store width; virtualized queues turn over a
        full directory of segments."""
        if self.family == "ring":
            return self.queue_capacity
        return self.max_segs * self.cfg.slots_per_segment(self.family)

    def describe(self, blocks: bool = False) -> str:
        """Human-readable offset table (DESIGN.md §7 is rendered from
        this, and a test pins the two together).  ``blocks=True``
        appends each region's blocked-lowering treatment (DESIGN.md §8;
        tests/test_arena_golden.py pins both renderings).

        The ``blocks=False`` rendering is ALSO the arena half of the
        serving snapshot fingerprint (DESIGN.md §12): a snapshotted
        arena word image restores only into an engine whose layout
        renders identically, so changing this string invalidates
        existing snapshots — loudly, which is the point."""
        lines = [f"arena(kind={self.kind}, family={self.family}): "
                 f"mem {self.mem_words} words, ctl {self.ctl_words} words"]
        for r in self.regions:
            tail = ""
            if blocks:
                bs = ("-" if r.block_shape is None
                      else "x".join(map(str, r.block_shape)))
                tail = f"  [{r.blocking}: block {bs}]"
            lines.append(f"  mem[{r.offset}:{r.end}]  {r.name} {r.shape}"
                         f"{tail}")
        C = self.num_classes
        for nm, off, w in (("front", self.off_front, C),
                           ("back", self.off_back, C),
                           ("head", self.off_head, C),
                           ("tail", self.off_tail, C),
                           ("pool_front", self.off_pool_front, 1),
                           ("pool_back", self.off_pool_back, 1)):
            lines.append(f"  ctl[{off}:{off + w}]  {nm}")
        for nm, off, w in self.tele_fields():
            lines.append(f"  ctl[{off}:{off + w}]  {nm}")
        return "\n".join(lines)


def queue_capacity(cfg: HeapConfig, kind: str) -> int:
    """Items the class queues must hold: every page of a class share
    (page kind) or every chunk id (chunk kind)."""
    if kind == "page":
        return cfg.data_chunks_per_class * cfg.pages_per_chunk(0)
    return cfg.num_chunks


@functools.lru_cache(maxsize=None)
def layout(cfg: HeapConfig, kind: str, family: str) -> ArenaLayout:
    """Compute the static arena layout for one allocator variant."""
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; pick from {KINDS}")
    if family not in QUEUE_FAMILIES:
        raise ValueError(
            f"unknown family {family!r}; pick from {QUEUE_FAMILIES}")
    C = cfg.num_classes
    cap = queue_capacity(cfg, kind)
    max_segs = cap // cfg.slots_per_segment(family) + 2

    # Per-region treatment under the blocked compiled lowering (see
    # Region.blocking and DESIGN.md §8).  Transactions never write the
    # heap for ring-family variants (segment traffic is what touches
    # it), and never write the pool for the plain page variant.
    heap_blk = "untouched" if family == "ring" else "hbm"
    pool_blk = ("untouched" if (family == "ring" and kind == "page")
                else "resident")
    regions = [Region("heap", 0, (cfg.total_words,), heap_blk)]

    def add(name, shape, blocking):
        regions.append(Region(name, regions[-1].end, shape, blocking))

    add("pool_store", (1, cfg.num_chunks), pool_blk)
    if family == "ring":
        add("queue_store", (C, cap), "row")
    else:
        add("directory", (C, max_segs), "row")
    if kind == "chunk":
        add("bitmap", (cfg.num_chunks, cfg.bitmap_words_per_chunk), "hbm")
        add("free_count", (cfg.num_chunks,), "resident")
        add("chunk_class", (cfg.num_chunks,), "resident")

    return ArenaLayout(cfg=cfg, kind=kind, family=family,
                       regions=tuple(regions), num_classes=C,
                       queue_capacity=cap, max_segs=max_segs)


# --------------------------------------------------------------------------
# pack / unpack: arena words <-> the legacy view pytrees
# --------------------------------------------------------------------------

def _take(lay: ArenaLayout, mem, name: str):
    r = lay.region(name)
    return jax.lax.slice(mem, (r.offset,), (r.end,)).reshape(r.shape)


def tele_of(lay: ArenaLayout, ctl):
    """View of the telemetry region inside one ctl block."""
    return jax.lax.slice(ctl, (lay.core_ctl_words,), (lay.ctl_words,))


def pack(lay: ArenaLayout, q, ctx: queues.AllocCtx,
         meta: Optional[ChunkMeta], tele=None) -> Arena:
    """Flatten the view pytrees into one (mem, ctl) arena.  ``tele`` is
    the telemetry region to carry into the rebuilt ctl block — ``None``
    (a fresh arena) zeroes it; transactions pass the incoming region
    through (obs/telemetry.py then applies the counter deltas)."""
    C = lay.num_classes
    parts = [ctx.heap, ctx.pool.store.reshape(-1)]
    if lay.family == "ring":
        parts.append(q.store.reshape(-1))
        head = tail = jnp.zeros(C, jnp.int32)
    else:
        parts.append(q.directory.reshape(-1))
        head, tail = q.head, q.tail
    if lay.kind == "chunk":
        parts.append(jax.lax.bitcast_convert_type(
            meta.bitmap, jnp.int32).reshape(-1))
        parts.append(meta.free_count)
        parts.append(meta.chunk_class)
    mem = jnp.concatenate(parts)
    if tele is None:
        tele = jnp.zeros(lay.tele_words, jnp.int32)
    ctl = jnp.concatenate([q.front, q.back, head, tail,
                           ctx.pool.front, ctx.pool.back,
                           tele]).astype(jnp.int32)
    return Arena(mem=mem, ctl=ctl)


def unpack(lay: ArenaLayout, arena: Arena):
    """Rebuild the (q, ctx, meta) views from arena words.  Pure static
    slices/reshapes — XLA fuses them away, so the views cost nothing."""
    C = lay.num_classes
    mem, ctl = arena.mem, arena.ctl
    front = jax.lax.slice(ctl, (lay.off_front,), (lay.off_front + C,))
    back = jax.lax.slice(ctl, (lay.off_back,), (lay.off_back + C,))
    pool = queues.RingState(
        store=_take(lay, mem, "pool_store"),
        front=jax.lax.slice(ctl, (lay.off_pool_front,),
                            (lay.off_pool_front + 1,)),
        back=jax.lax.slice(ctl, (lay.off_pool_back,),
                           (lay.off_pool_back + 1,)))
    ctx = queues.AllocCtx(heap=heap_of(lay, arena), pool=pool)
    if lay.family == "ring":
        q = queues.RingState(store=_take(lay, mem, "queue_store"),
                             front=front, back=back)
    else:
        q = queues.VirtState(
            directory=_take(lay, mem, "directory"),
            head=jax.lax.slice(ctl, (lay.off_head,), (lay.off_head + C,)),
            tail=jax.lax.slice(ctl, (lay.off_tail,), (lay.off_tail + C,)),
            front=front, back=back)
    meta = None
    if lay.kind == "chunk":
        meta = ChunkMeta(
            bitmap=jax.lax.bitcast_convert_type(
                _take(lay, mem, "bitmap"), jnp.uint32),
            free_count=_take(lay, mem, "free_count"),
            chunk_class=_take(lay, mem, "chunk_class"))
    return q, ctx, meta


def heap_of(lay: ArenaLayout, arena: Arena):
    """View of the heap proper (the paper's word array) inside ``mem``."""
    return jax.lax.slice(arena.mem, (0,), (lay.cfg.total_words,))


def with_heap(lay: ArenaLayout, arena: Arena, heap) -> Arena:
    """Arena with the heap region replaced (offset 0, so one update)."""
    return arena._replace(
        mem=jax.lax.dynamic_update_slice(arena.mem, heap, (0,)))


# --------------------------------------------------------------------------
# region split / join: mem <-> one flat array per region
# --------------------------------------------------------------------------
#
# The blocked lowering never hands the kernel the whole ``mem`` image as
# one ref; the wrapper splits it into its regions (static slices — XLA
# fuses them away) so each region can ride its own BlockSpec, and joins
# the touched regions back afterwards.  Regions the transaction cannot
# write (Region.blocking == "untouched") bypass the kernel entirely and
# are reused verbatim in the join.

def split(lay: ArenaLayout, mem):
    """``mem`` as a dict of flat per-region arrays (zero-cost views)."""
    return {r.name: jax.lax.slice(mem, (r.offset,), (r.end,))
            for r in lay.regions}


def join(lay: ArenaLayout, parts) -> Any:
    """Inverse of :func:`split`: concatenate region arrays (flattened,
    in layout order) back into one ``mem`` image."""
    return jnp.concatenate([parts[r.name].reshape(-1)
                            for r in lay.regions])
