"""Unified allocator transactions over the device-resident arena.

This is the single dispatcher the ISSUE calls for: every variant is a
``(kind, family)`` pair — ``kind`` picks the item algorithm (page
inventory vs chunk bitmap claim, the former ``page_alloc``/
``chunk_alloc`` split) and ``family`` the queue machinery (ring / va /
vl) — and every transaction runs against one :class:`arena.Arena`.

Two execution paths share one body:

``*_math``   the pure transaction math ``(mem, ctl, …) → (mem', ctl', …)``.
             It unpacks the arena into the legacy view pytrees, runs the
             jnp reference algorithms (``page_alloc``/``chunk_alloc``
             with their internal backend pinned to ``"jnp"``), and packs
             the result.  Views are static slices — XLA sees one fused
             program over two flat arrays.

``alloc``/``free``   the public dispatcher.  ``backend="jnp"`` calls the
             math directly (the oracle); ``backend="pallas"`` hands the
             transaction to ONE ``pallas_call`` executing it whole —
             masked rank, inventory grant, ring pop/push, chunk-bitmap
             claim, and the va/vl segment walk with its grow/shrink
             against the chunk pool — under the ``lowering`` the
             dispatcher stitches in (kernels/ops.resolve_lowering):

             ``whole``    the kernel body IS this module's math over
                          full ``mem``/``ctl`` refs (kernels/alloc_txn)
                          — parity with the oracle is structural;
             ``blocked``  the region-blocked compiled lowering
                          (kernels/alloc_txn_blocked, DESIGN.md §8):
                          the same math split into per-region,
                          per-class bodies driven by the ArenaLayout
                          region table — parity is enforced word for
                          word by the three-way differential matrix.

             tests/test_alloc_txn_parity.py holds all implementations
             bit-identical and asserts the one-kernel property on the
             jaxpr for both lowerings.

The ``sharded_*`` entry points are the same contract over a
:class:`~repro.core.shards.ShardedArena` (num_shards independent
arenas, home-shard routing, bounded overflow walk — DESIGN.md §9):
``sharded_alloc_math``/``sharded_free_math`` are the serial
single-shard replay oracle, and the Pallas backends grid that exact
schedule into ONE pallas_call per transaction.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import arena, chunk_alloc, defrag, page_alloc, shards
from repro.core.heap import HeapConfig
from repro.core.page_alloc import AllocState


def _impl(kind: str):
    return page_alloc if kind == "page" else chunk_alloc


def _views(cfg: HeapConfig, kind: str, family: str, mem, ctl):
    lay = arena.layout(cfg, kind, family)
    q, ctx, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    return lay, AllocState(q=q, ctx=ctx, meta=meta)


def init(cfg: HeapConfig, kind: str, family: str, num_shards: int = 1):
    """Build the arena (backend-free, so a live heap can switch
    backends mid-stream — asserted by the parity tests).  With
    ``num_shards > 1`` the state is a :class:`shards.ShardedArena` of
    ``num_shards`` identical fresh per-shard arenas."""
    if num_shards != 1:
        return shards.init(cfg, num_shards, kind, family)
    lay = arena.layout(cfg, kind, family)
    st = _impl(kind).init(cfg, family)
    return arena.pack(lay, st.q, st.ctx, st.meta)


# ---- pure transaction math (shared by both backends) ----------------------
#
# Telemetry (DESIGN.md §14): both transactions thread the incoming ctl
# telemetry region through ``arena.pack`` and advance it via the
# obs/telemetry.py delta math — the whole-lowering kernel body calls
# these functions directly, so its telemetry is structurally identical;
# the blocked lowering reproduces the same deltas per class step.

def alloc_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
               sizes_bytes, mask, attempt=0) -> Tuple:
    """``attempt`` is the overflow-walk attempt this call serves — 0
    for single-arena traffic; the sharded schedule passes its attempt
    index (traced) so the walk-depth histogram attributes lanes to the
    attempt that actually served them."""
    from repro.obs import telemetry
    lay, st = _views(cfg, kind, family, mem, ctl)
    st, offs = _impl(kind).alloc(cfg, family, st, sizes_bytes, mask, "jnp")
    new = arena.pack(lay, st.q, st.ctx, st.meta,
                     tele=arena.tele_of(lay, ctl))
    new_ctl = telemetry.alloc_update(lay, ctl, new.ctl, sizes_bytes,
                                     mask, offs, attempt)
    return new.mem, new_ctl, offs


def free_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
              offsets_words, sizes_bytes, mask) -> Tuple:
    from repro.obs import telemetry
    lay, st = _views(cfg, kind, family, mem, ctl)
    st = _impl(kind).free(cfg, family, st, offsets_words, sizes_bytes,
                          mask, "jnp")
    new = arena.pack(lay, st.q, st.ctx, st.meta,
                     tele=arena.tele_of(lay, ctl))
    new_ctl = telemetry.free_update(lay, ctl, new.ctl, sizes_bytes,
                                    mask, offsets_words)
    return new.mem, new_ctl


# ---- grow-to-target-lens lane routing (decode mega-step entry) ------------

def grow_lanes(need, lanes: int):
    """Expand a DEVICE per-slot page-need vector into allocation lanes.

    The decode mega-step computes ``need[b]`` (how many new pages slot
    ``b`` must be granted this tick) from device-resident sequence
    lengths — no host slot list exists.  This routine turns that vector
    into the lane layout every alloc transaction consumes: lane ``j``
    carries slot ``slot[j]``'s ``rank[j]``-th new page, slots packed in
    slot order (the same order the engine's host loop used), and
    ``mask[j]`` marks live lanes.  Pure jnp, shared verbatim by both
    backends and both Pallas lowerings, so lane routing can never
    diverge between them.  Lanes beyond ``sum(need)`` are masked;
    demand beyond ``lanes`` is silently truncated — callers detect the
    shortfall by comparing granted counts against ``need``.

    >>> import jax.numpy as jnp
    >>> from repro.core.transactions import grow_lanes
    >>> slot, rank, mask = grow_lanes(jnp.array([2, 0, 1]), lanes=4)
    >>> slot.tolist(), rank.tolist(), mask.tolist()
    ([0, 0, 2, 2], [0, 1, 0, 0], [True, True, True, False])
    """
    need = need.astype(jnp.int32)
    B = need.shape[0]
    cum = jnp.cumsum(need)
    j = jnp.arange(lanes, dtype=jnp.int32)
    mask = j < cum[-1]
    slot = jnp.minimum(
        jnp.searchsorted(cum, j, side="right").astype(jnp.int32), B - 1)
    rank = jnp.where(mask, j - (cum[slot] - need[slot]), 0)
    return slot, rank, mask


# ---- public dispatcher ----------------------------------------------------

BACKENDS = ("jnp", "pallas")


def _check_backend(backend: str) -> None:
    # Fail loudly here too, not only in the Ouroboros facade: a typo
    # like "palas" must never silently fall through to the jnp branch
    # for callers that reach the dispatcher directly.
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; pick from {BACKENDS}")


def alloc(cfg: HeapConfig, kind: str, family: str, state: arena.Arena,
          sizes_bytes, mask, backend: str = "jnp",
          lowering: str = "auto"):
    """One bulk allocation transaction.  Returns (arena', word_offsets);
    offset −1 marks a failed lane (over-large size / exhausted
    inventory), matching the GPU original's nullptr.  ``lowering``
    picks the Pallas kernel shape (whole-arena refs vs the
    region-blocked compiled lowering — kernels/ops.resolve_lowering).

    The dispatcher is the layer below the ``Ouroboros`` facade — same
    semantics, explicit (kind, family):

    >>> import jax.numpy as jnp
    >>> from repro.core import HeapConfig, transactions
    >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
    ...                  min_page_bytes=16)
    >>> st = transactions.init(cfg, "page", "ring")
    >>> sizes = jnp.full(2, 64, jnp.int32)
    >>> st, offs = transactions.alloc(cfg, "page", "ring", st, sizes,
    ...                               jnp.ones(2, bool))
    >>> bool((offs >= 0).all())
    True
    """
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl, offs = kops.arena_alloc_txn(cfg, kind, family,
                                              state.mem, state.ctl,
                                              sizes_bytes, mask,
                                              lowering=lowering)
    else:
        mem, ctl, offs = alloc_math(cfg, kind, family, state.mem,
                                    state.ctl, sizes_bytes, mask)
    return arena.Arena(mem=mem, ctl=ctl), offs


def free(cfg: HeapConfig, kind: str, family: str, state: arena.Arena,
         offsets_words, sizes_bytes, mask, backend: str = "jnp",
         lowering: str = "auto"):
    """One bulk free transaction (inverse of :func:`alloc`; masked or
    negative-offset lanes are no-ops).  Freed pages become grantable
    again immediately:

    >>> import jax.numpy as jnp
    >>> from repro.core import HeapConfig, transactions
    >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
    ...                  min_page_bytes=16)
    >>> st = transactions.init(cfg, "page", "ring")
    >>> sizes = jnp.full(2, 64, jnp.int32)
    >>> ones = jnp.ones(2, bool)
    >>> st, offs = transactions.alloc(cfg, "page", "ring", st, sizes,
    ...                               ones)
    >>> st = transactions.free(cfg, "page", "ring", st, offs, sizes,
    ...                        ones)
    >>> st, offs2 = transactions.alloc(cfg, "page", "ring", st, sizes,
    ...                                ones)
    >>> bool((offs2 >= 0).all())
    True
    """
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl = kops.arena_free_txn(cfg, kind, family, state.mem,
                                       state.ctl, offsets_words,
                                       sizes_bytes, mask,
                                       lowering=lowering)
    else:
        mem, ctl = free_math(cfg, kind, family, state.mem, state.ctl,
                             offsets_words, sizes_bytes, mask)
    return arena.Arena(mem=mem, ctl=ctl)


def compact(cfg: HeapConfig, kind: str, family: str,
            state: arena.Arena) -> arena.Arena:
    """Host-triggered chunk-rebind pass (chunk kinds only; DESIGN.md
    §5b).  Rebuilt queues repack into the identical layout.  Releases
    sticky bindings but never moves a live word — :func:`migrate` is
    the true defragmentation pass."""
    if kind != "chunk":
        return state
    lay, st = _views(cfg, kind, family, state.mem, state.ctl)
    st = chunk_alloc.compact(cfg, family, st)
    # not allocator traffic: telemetry words carry through unchanged
    return arena.pack(lay, st.q, st.ctx, st.meta,
                      tele=arena.tele_of(lay, state.ctl))


# ---- defragmentation: plan (shared jnp oracle) + migrate (execute) --------

def defrag_plan(cfg: HeapConfig, kind: str, family: str,
                state: arena.Arena, max_moves: int):
    """Relocation plan for one wave (core/defrag.py, DESIGN.md §10).
    Pure jnp, computed ONCE and shared verbatim by every backend —
    the forwarding-table analogue of ``shards.home_shards``."""
    return defrag.plan_math(cfg, kind, family, state.mem, state.ctl,
                            max_moves=max_moves)


def migrate(cfg: HeapConfig, kind: str, family: str, state: arena.Arena,
            src, dst, sizes, backend: str = "jnp",
            lowering: str = "auto") -> arena.Arena:
    """Execute one migration wave (copy extents, flip bitmap bits,
    retire emptied chunks, rebuild queues).  ``backend="pallas"`` runs
    the whole wave as ONE pallas_call (kernels/defrag_txn.py) under
    either lowering; ``"jnp"`` is the replay oracle — bit-identical,
    word for word (tests/test_defrag.py)."""
    _check_backend(backend)
    if kind != "chunk":
        return state
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl = kops.arena_defrag_txn(cfg, kind, family, state.mem,
                                         state.ctl, src, dst, sizes,
                                         lowering=lowering)
    else:
        mem, ctl = defrag.migrate_math(cfg, kind, family, state.mem,
                                       state.ctl, src, dst, sizes)
    return arena.Arena(mem=mem, ctl=ctl)


# ---------------------------------------------------------------------------
# sharded transactions: serial replay oracle + the sharded dispatcher
# ---------------------------------------------------------------------------
#
# The sharded correctness contract (DESIGN.md §9) is a SCHEDULE: a bulk
# transaction over S shards behaves exactly as if the wavefront were
# replayed serially through S independent single-arena allocators,
# attempt-major then shard-minor —
#
#     for attempt a in 0..walk:
#         for shard s in 0..S-1:
#             serve the still-unserved lanes whose (home + a) % S == s
#
# ``sharded_alloc_math``/``sharded_free_math`` below ARE that replay
# (the jnp oracle); the Pallas lowerings grid the same schedule into
# ONE pallas_call (kernels/alloc_txn.sharded_arena_*_txn and
# kernels/alloc_txn_blocked.sharded_arena_*_txn_blocked), so
# bit-identity with the serial replay is checked word for word by
# tests/test_alloc_txn_parity.py.

def sharded_alloc_math(cfg: HeapConfig, num_shards: int, kind: str,
                       family: str, mem, ctl, sizes_bytes, mask, home,
                       walk: int) -> Tuple:
    """Serial single-shard oracle replay of one sharded alloc.  Lanes
    route to ``home`` first; lanes a shard cannot serve retry on the
    next ``walk`` neighbor shards.  Returns (mem', ctl', offsets) with
    offsets GLOBAL (shard · shard_words + local; −1 = every visited
    shard failed the lane).

    The replay is a nested ``lax.scan`` over (attempt, shard) rather
    than an unrolled loop: the schedule is identical step for step (so
    results are bit-identical to the gridded kernels), but the
    single-arena transaction math compiles ONCE instead of
    (walk+1)·num_shards times — for chunk variants that is the
    difference between seconds and minutes of XLA compile."""
    import jax

    scfg = shards.shard_config(cfg, num_shards)
    Ws = scfg.total_words
    n = sizes_bytes.shape[0]
    S = num_shards

    def shard_step(carry, s):
        mem, ctl, offs, a = carry
        sel = mask & ((home + a) % S == s) & (offs < 0)
        m2, c2, local = alloc_math(
            scfg, kind, family,
            jax.lax.dynamic_index_in_dim(mem, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ctl, s, 0, keepdims=False),
            sizes_bytes, sel, attempt=a)
        mem = jax.lax.dynamic_update_index_in_dim(mem, m2, s, 0)
        ctl = jax.lax.dynamic_update_index_in_dim(ctl, c2, s, 0)
        offs = jnp.where(sel & (local >= 0), s * Ws + local, offs)
        return (mem, ctl, offs, a), None

    def attempt_step(carry, a):
        mem, ctl, offs = carry
        (mem, ctl, offs, _), _ = jax.lax.scan(
            shard_step, (mem, ctl, offs, a),
            jnp.arange(S, dtype=jnp.int32))
        return (mem, ctl, offs), None

    offs0 = jnp.full(n, -1, jnp.int32)
    (mem, ctl, offs), _ = jax.lax.scan(
        attempt_step, (mem, ctl, offs0),
        jnp.arange(walk + 1, dtype=jnp.int32))
    return mem, ctl, offs


def sharded_free_math(cfg: HeapConfig, num_shards: int, kind: str,
                      family: str, mem, ctl, offsets_words, sizes_bytes,
                      mask) -> Tuple:
    """Serial replay of one sharded free: each lane's owning shard is
    determined by its global offset (no overflow walk — an offset lives
    on exactly one shard), shards visited in order (a ``lax.scan``, as
    in :func:`sharded_alloc_math`)."""
    import jax

    scfg = shards.shard_config(cfg, num_shards)
    Ws = scfg.total_words
    sh = jnp.where(offsets_words >= 0, offsets_words // Ws, -1)

    def shard_step(carry, s):
        mem, ctl = carry
        sel = mask & (sh == s)
        local = jnp.where(sel, offsets_words - s * Ws, -1)
        m2, c2 = free_math(
            scfg, kind, family,
            jax.lax.dynamic_index_in_dim(mem, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ctl, s, 0, keepdims=False),
            local, sizes_bytes, sel)
        mem = jax.lax.dynamic_update_index_in_dim(mem, m2, s, 0)
        ctl = jax.lax.dynamic_update_index_in_dim(ctl, c2, s, 0)
        return (mem, ctl), None

    (mem, ctl), _ = jax.lax.scan(shard_step, (mem, ctl),
                                 jnp.arange(num_shards, dtype=jnp.int32))
    return mem, ctl


def sharded_alloc(cfg: HeapConfig, num_shards: int, kind: str,
                  family: str, state: shards.ShardedArena, sizes_bytes,
                  mask, home, walk: int, backend: str = "jnp",
                  lowering: str = "auto"):
    """One bulk sharded allocation transaction (see module docstring
    for the schedule).  ``home`` is the per-lane home-shard vector
    (``shards.home_shards``), shared by every backend so routing can
    never diverge.  Still ONE pallas_call under ``backend="pallas"``:
    the kernels grid the (attempt, shard) schedule."""
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl, offs = kops.sharded_arena_alloc_txn(
            cfg, num_shards, kind, family, state.mem, state.ctl,
            sizes_bytes, mask, home, walk, lowering=lowering)
    else:
        mem, ctl, offs = sharded_alloc_math(
            cfg, num_shards, kind, family, state.mem, state.ctl,
            sizes_bytes, mask, home, walk)
    return shards.ShardedArena(mem=mem, ctl=ctl), offs


def sharded_free(cfg: HeapConfig, num_shards: int, kind: str,
                 family: str, state: shards.ShardedArena, offsets_words,
                 sizes_bytes, mask, backend: str = "jnp",
                 lowering: str = "auto"):
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl = kops.sharded_arena_free_txn(
            cfg, num_shards, kind, family, state.mem, state.ctl,
            offsets_words, sizes_bytes, mask, lowering=lowering)
    else:
        mem, ctl = sharded_free_math(
            cfg, num_shards, kind, family, state.mem, state.ctl,
            offsets_words, sizes_bytes, mask)
    return shards.ShardedArena(mem=mem, ctl=ctl)


def sharded_compact(cfg: HeapConfig, num_shards: int, kind: str,
                    family: str,
                    state: shards.ShardedArena) -> shards.ShardedArena:
    """Per-shard chunk rebind (shards are independent heaps)."""
    if kind != "chunk":
        return state
    scfg = shards.shard_config(cfg, num_shards)
    subs = [compact(scfg, kind, family, shards.take_shard(state, s))
            for s in range(num_shards)]
    return shards.ShardedArena(mem=jnp.stack([a.mem for a in subs]),
                               ctl=jnp.stack([a.ctl for a in subs]))


def sharded_defrag_plan(cfg: HeapConfig, num_shards: int, kind: str,
                        family: str, state: shards.ShardedArena,
                        max_moves: int):
    """Per-shard compaction plans merged to GLOBAL offsets (cross-shard
    rebalance plans come from ``shards.rebalance_plan_math``; both
    execute through :func:`sharded_migrate`)."""
    return defrag.sharded_plan_math(cfg, num_shards, kind, family,
                                    state.mem, state.ctl,
                                    max_moves=max_moves)


def sharded_migrate(cfg: HeapConfig, num_shards: int, kind: str,
                    family: str, state: shards.ShardedArena, src, dst,
                    sizes, backend: str = "jnp",
                    lowering: str = "auto") -> shards.ShardedArena:
    """Execute one sharded migration wave: extract every source shard's
    extents into a carry buffer, then insert + rebuild every shard —
    the (phase, shard) schedule ``defrag.sharded_migrate_math`` replays
    serially and both Pallas lowerings grid into ONE pallas_call.
    Cross-shard moves (rebalancing) ride the same wave."""
    _check_backend(backend)
    if kind != "chunk":
        return state
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl = kops.sharded_arena_defrag_txn(
            cfg, num_shards, kind, family, state.mem, state.ctl, src,
            dst, sizes, lowering=lowering)
    else:
        mem, ctl = defrag.sharded_migrate_math(
            cfg, num_shards, kind, family, state.mem, state.ctl, src,
            dst, sizes)
    return shards.ShardedArena(mem=mem, ctl=ctl)
