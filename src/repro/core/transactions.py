"""Unified allocator transactions over the device-resident arena.

This is the single dispatcher the ISSUE calls for: every variant is a
``(kind, family)`` pair — ``kind`` picks the item algorithm (page
inventory vs chunk bitmap claim, the former ``page_alloc``/
``chunk_alloc`` split) and ``family`` the queue machinery (ring / va /
vl) — and every transaction runs against one :class:`arena.Arena`.

Two execution paths share one body:

``*_math``   the pure transaction math ``(mem, ctl, …) → (mem', ctl', …)``.
             It unpacks the arena into the legacy view pytrees, runs the
             jnp reference algorithms (``page_alloc``/``chunk_alloc``
             with their internal backend pinned to ``"jnp"``), and packs
             the result.  Views are static slices — XLA sees one fused
             program over two flat arrays.

``alloc``/``free``   the public dispatcher.  ``backend="jnp"`` calls the
             math directly (the oracle); ``backend="pallas"`` hands the
             transaction to ONE ``pallas_call`` executing it whole —
             masked rank, inventory grant, ring pop/push, chunk-bitmap
             claim, and the va/vl segment walk with its grow/shrink
             against the chunk pool — under the ``lowering`` the
             dispatcher stitches in (kernels/ops.resolve_lowering):

             ``whole``    the kernel body IS this module's math over
                          full ``mem``/``ctl`` refs (kernels/alloc_txn)
                          — parity with the oracle is structural;
             ``blocked``  the region-blocked compiled lowering
                          (kernels/alloc_txn_blocked, DESIGN.md §8):
                          the same math split into per-region,
                          per-class bodies driven by the ArenaLayout
                          region table — parity is enforced word for
                          word by the three-way differential matrix.

             tests/test_alloc_txn_parity.py holds all implementations
             bit-identical and asserts the one-kernel property on the
             jaxpr for both lowerings.
"""
from __future__ import annotations

from typing import Tuple

from repro.core import arena, chunk_alloc, page_alloc
from repro.core.heap import HeapConfig
from repro.core.page_alloc import AllocState


def _impl(kind: str):
    return page_alloc if kind == "page" else chunk_alloc


def _views(cfg: HeapConfig, kind: str, family: str, mem, ctl):
    lay = arena.layout(cfg, kind, family)
    q, ctx, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    return lay, AllocState(q=q, ctx=ctx, meta=meta)


def init(cfg: HeapConfig, kind: str, family: str) -> arena.Arena:
    """Build the arena (backend-free, so a live heap can switch
    backends mid-stream — asserted by the parity tests)."""
    lay = arena.layout(cfg, kind, family)
    st = _impl(kind).init(cfg, family)
    return arena.pack(lay, st.q, st.ctx, st.meta)


# ---- pure transaction math (shared by both backends) ----------------------

def alloc_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
               sizes_bytes, mask) -> Tuple:
    lay, st = _views(cfg, kind, family, mem, ctl)
    st, offs = _impl(kind).alloc(cfg, family, st, sizes_bytes, mask, "jnp")
    new = arena.pack(lay, st.q, st.ctx, st.meta)
    return new.mem, new.ctl, offs


def free_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
              offsets_words, sizes_bytes, mask) -> Tuple:
    lay, st = _views(cfg, kind, family, mem, ctl)
    st = _impl(kind).free(cfg, family, st, offsets_words, sizes_bytes,
                          mask, "jnp")
    new = arena.pack(lay, st.q, st.ctx, st.meta)
    return new.mem, new.ctl


# ---- public dispatcher ----------------------------------------------------

BACKENDS = ("jnp", "pallas")


def _check_backend(backend: str) -> None:
    # Fail loudly here too, not only in the Ouroboros facade: a typo
    # like "palas" must never silently fall through to the jnp branch
    # for callers that reach the dispatcher directly.
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; pick from {BACKENDS}")


def alloc(cfg: HeapConfig, kind: str, family: str, state: arena.Arena,
          sizes_bytes, mask, backend: str = "jnp",
          lowering: str = "auto"):
    """One bulk allocation transaction.  Returns (arena', word_offsets);
    offset −1 marks a failed lane (over-large size / exhausted
    inventory), matching the GPU original's nullptr.  ``lowering``
    picks the Pallas kernel shape (whole-arena refs vs the
    region-blocked compiled lowering — kernels/ops.resolve_lowering)."""
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl, offs = kops.arena_alloc_txn(cfg, kind, family,
                                              state.mem, state.ctl,
                                              sizes_bytes, mask,
                                              lowering=lowering)
    else:
        mem, ctl, offs = alloc_math(cfg, kind, family, state.mem,
                                    state.ctl, sizes_bytes, mask)
    return arena.Arena(mem=mem, ctl=ctl), offs


def free(cfg: HeapConfig, kind: str, family: str, state: arena.Arena,
         offsets_words, sizes_bytes, mask, backend: str = "jnp",
         lowering: str = "auto"):
    _check_backend(backend)
    if backend == "pallas":
        from repro.kernels import ops as kops
        mem, ctl = kops.arena_free_txn(cfg, kind, family, state.mem,
                                       state.ctl, offsets_words,
                                       sizes_bytes, mask,
                                       lowering=lowering)
    else:
        mem, ctl = free_math(cfg, kind, family, state.mem, state.ctl,
                             offsets_words, sizes_bytes, mask)
    return arena.Arena(mem=mem, ctl=ctl)


def compact(cfg: HeapConfig, kind: str, family: str,
            state: arena.Arena) -> arena.Arena:
    """Host-triggered defragmentation pass (chunk kinds only; DESIGN.md
    §5b).  Rebuilt queues repack into the identical layout."""
    if kind != "chunk":
        return state
    lay, st = _views(cfg, kind, family, state.mem, state.ctl)
    st = chunk_alloc.compact(cfg, family, st)
    return arena.pack(lay, st.q, st.ctx, st.meta)
