"""Sharded multi-arena allocator: N independent arenas + overflow routing.

The single device-resident arena (core/arena.py) funnels every request
through one set of rings, directories, and bitmaps.  That is the right
shape for one kernel, but the paper's headline claim is throughput
under *massive concurrency* — and the serving north star ("heavy
traffic from millions of users", ROADMAP) needs the allocator to scale
horizontally.  This module partitions the heap into ``num_shards``
independent arenas:

    ``ShardedArena.mem``  (S, shard_mem_words) — shard ``s``'s word
                          image is row ``s``, laid out by the SAME
                          :class:`~repro.core.arena.ArenaLayout` as a
                          single arena of ``total_bytes / S`` (so
                          ``arena.split``/``join`` and every region
                          offset work per shard unchanged);
    ``ShardedArena.ctl``  (S, shard_ctl_words) — one control block per
                          shard.

Routing (DESIGN.md §9): every request lane gets a **home shard** —
``hash(lane) % S`` by default, or an explicit ``shard_hint`` from the
caller (the KV cache pins each sequence's pages this way) — and a
transaction serves lanes **attempt-major, shard-minor**: attempt 0
visits each shard with its home lanes; lanes a shard could not serve
retry on ``home + 1, home + 2, …`` (mod S) up to a bounded **overflow
walk** (default: all S−1 neighbors, so a request only fails once every
shard is exhausted).  Offsets returned to callers are GLOBAL heap word
offsets: ``global = shard * shard_words + local``.

The replay order is the correctness contract: the jnp oracle
(``transactions.sharded_alloc_math``) literally replays the wavefront
through the per-shard single-arena math in that order, and both Pallas
lowerings grid the SAME schedule into one ``pallas_call``
(kernels/alloc_txn.sharded_* and alloc_txn_blocked.sharded_*), so all
implementations are bit-identical to a serial single-shard oracle
replay (tests/test_alloc_txn_parity.py).

With a *static* ``shard_hint`` and ``overflow_walk=0`` the transaction
touches exactly one shard, and the other S−1 rows bypass the kernel
entirely (static slices around the single-arena kernel) — the shard
analogue of ``Region.blocking == "untouched"``.
"""
from __future__ import annotations

import dataclasses
import functools
import numbers
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import arena
from repro.core.heap import HeapConfig

# Knuth's multiplicative hash constant (2^32 / golden ratio): cheap,
# well-mixing lane -> home-shard map that both the oracle and the
# kernels receive as a precomputed lane vector.
_HASH_MULT = 2654435761


class ShardedArena(NamedTuple):
    """Stacked per-shard allocator state (see module docstring).

    >>> from repro.core import HeapConfig, shards
    >>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
    ...                  min_page_bytes=16)
    >>> st = shards.init(cfg, 4, "page", "ring")
    >>> st.num_shards, st.mem.ndim, st.ctl.ndim
    (4, 2, 2)
    """
    mem: Any  # (num_shards, shard mem_words) int32
    ctl: Any  # (num_shards, shard ctl_words) int32

    @property
    def num_shards(self) -> int:
        return self.mem.shape[0]


def shard_config(cfg: HeapConfig, num_shards: int) -> HeapConfig:
    """The per-shard HeapConfig: same chunk/page geometry, 1/S of the
    bytes.  Shard boundaries are chunk boundaries by construction."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if cfg.num_chunks % num_shards:
        raise ValueError(
            f"num_shards={num_shards} must divide num_chunks="
            f"{cfg.num_chunks} (shards split the heap chunk-wise)")
    return dataclasses.replace(
        cfg, total_bytes=cfg.total_bytes // num_shards)


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Static layout of a sharded arena: ``num_shards`` copies of one
    per-shard :class:`~repro.core.arena.ArenaLayout` (``self.shard``),
    plus the global-offset convention.  DESIGN.md §9 is rendered from
    ``describe()`` (tests/golden/shard_layout.txt pins it)."""
    cfg: HeapConfig            # the GLOBAL heap config
    num_shards: int
    kind: str
    family: str

    @property
    def shard_cfg(self) -> HeapConfig:
        return shard_config(self.cfg, self.num_shards)

    @property
    def shard(self) -> arena.ArenaLayout:
        """The per-shard arena layout (every offset is shard-local)."""
        return arena.layout(self.shard_cfg, self.kind, self.family)

    @property
    def shard_words(self) -> int:
        """Heap words per shard: global offset = s·shard_words + local."""
        return self.shard_cfg.total_words

    @property
    def mem_words(self) -> int:
        return self.shard.mem_words

    @property
    def ctl_words(self) -> int:
        return self.shard.ctl_words

    def describe(self, blocks: bool = False) -> str:
        """Human-readable shard table + the per-shard §7/§8 rendering
        (DESIGN.md §9 embeds this; tests pin doc and code together).
        Like ``ArenaLayout.describe``, the ``blocks=False`` rendering
        doubles as the serving snapshot fingerprint's layout field
        (DESIGN.md §12) — changing it invalidates existing sharded
        snapshots loudly."""
        S = self.num_shards
        lines = [
            f"sharded arena(kind={self.kind}, family={self.family}, "
            f"num_shards={S}): mem {S}x{self.mem_words} words, "
            f"ctl {S}x{self.ctl_words} words",
            f"  global heap offset = shard * {self.shard_words} + local; "
            f"home = hash(lane) % {S} or shard_hint; overflow walk "
            f"retries home+1..home+{S - 1} (mod {S})",
        ]
        lines += ["  " + ln
                  for ln in self.shard.describe(blocks=blocks).splitlines()]
        return "\n".join(lines)


@functools.lru_cache(maxsize=None)
def layout(cfg: HeapConfig, num_shards: int, kind: str,
           family: str) -> ShardLayout:
    shard_config(cfg, num_shards)  # validate divisibility early
    arena.layout(shard_config(cfg, num_shards), kind, family)
    return ShardLayout(cfg=cfg, num_shards=num_shards, kind=kind,
                       family=family)


def resolve_walk(num_shards: int, overflow_walk: Optional[int]) -> int:
    """Concrete overflow-walk length: how many NEIGHBOR shards a lane
    may retry after its home shard fails.  ``None`` = all S−1 neighbors
    (a request fails only when every shard is exhausted)."""
    if overflow_walk is None:
        return num_shards - 1
    if not isinstance(overflow_walk, int) or overflow_walk < 0:
        raise ValueError(
            f"overflow_walk must be None or an int >= 0, got "
            f"{overflow_walk!r}")
    return min(overflow_walk, num_shards - 1)


def static_hint(shard_hint) -> Optional[int]:
    """``shard_hint`` as a static Python int when it is one (incl.
    numpy integer scalars), else None — the predicate deciding whether
    the pinned fast path can apply."""
    if shard_hint is None or isinstance(shard_hint, bool):
        return None
    if isinstance(shard_hint, numbers.Integral):
        return int(shard_hint)
    return None


def home_shards(n: int, num_shards: int, shard_hint=None):
    """Per-lane home-shard vector, shared verbatim by the oracle and
    both kernel lowerings (so routing can never diverge between them).

    ``shard_hint=None`` hashes the lane index; an integer pins every
    lane to one shard; an array gives per-lane homes (e.g. the KV
    cache routing each sequence slot to ``slot % S``)."""
    if shard_hint is None:
        i = jnp.arange(n, dtype=jnp.uint32)
        h = i * jnp.uint32(_HASH_MULT)
        h = h ^ (h >> jnp.uint32(16))
        return (h % jnp.uint32(num_shards)).astype(jnp.int32)
    pinned = static_hint(shard_hint)
    if pinned is not None:
        return jnp.full(n, pinned % num_shards, jnp.int32)
    hint = jnp.asarray(shard_hint, jnp.int32)
    if hint.shape != (n,):
        raise ValueError(
            f"shard_hint array must have shape ({n},), got {hint.shape}")
    return hint % num_shards


def init(cfg: HeapConfig, num_shards: int, kind: str,
         family: str) -> ShardedArena:
    """S identical fresh shards (each shard inits exactly like a
    single arena of the per-shard config — backend- and lowering-free,
    like ``transactions.init``)."""
    from repro.core import transactions  # lazy: shards <-> transactions
    sub = transactions.init(shard_config(cfg, num_shards), kind, family)
    return ShardedArena(mem=jnp.tile(sub.mem[None], (num_shards, 1)),
                        ctl=jnp.tile(sub.ctl[None], (num_shards, 1)))


# --------------------------------------------------------------------------
# views: global heap, per-shard slabs, per-region stacks
# --------------------------------------------------------------------------

def heap_of(slay: ShardLayout, state: ShardedArena):
    """The GLOBAL heap view (S·shard_words,): per-shard heap regions
    concatenated in shard order, so global word offsets index it
    directly (write_pattern/check_pattern run on this view)."""
    W = slay.shard_words
    return jax.lax.slice(state.mem, (0, 0),
                         (slay.num_shards, W)).reshape(-1)


def with_heap(slay: ShardLayout, state: ShardedArena,
              heap) -> ShardedArena:
    """State with the global heap view replaced (inverse of heap_of)."""
    W = slay.shard_words
    return state._replace(mem=jax.lax.dynamic_update_slice(
        state.mem, heap.reshape(slay.num_shards, W), (0, 0)))


def shard_of(slay: ShardLayout, offsets_words):
    """Owning shard of each global offset (−1 for failed lanes)."""
    return jnp.where(offsets_words >= 0,
                     offsets_words // slay.shard_words, -1)


def take_shard(state: ShardedArena, s: int) -> arena.Arena:
    """Shard ``s``'s slab as a plain single-arena state (static slice:
    the pinned fast path runs the single-arena kernel on exactly this,
    and the other shards never enter the kernel)."""
    return arena.Arena(mem=state.mem[s], ctl=state.ctl[s])


def with_shard(state: ShardedArena, s: int,
               sub: arena.Arena) -> ShardedArena:
    """Inverse of :func:`take_shard`: replace one shard's slab."""
    return ShardedArena(mem=state.mem.at[s].set(sub.mem),
                        ctl=state.ctl.at[s].set(sub.ctl))


# --------------------------------------------------------------------------
# cross-shard rebalancing: plan moves from the most- to the least-loaded
# --------------------------------------------------------------------------

def shard_live_words(cfg: HeapConfig, num_shards: int, kind: str,
                     family: str, mem, ctl):
    """(S,) live heap words per shard (bound chunks' occupied pages) —
    the load metric the rebalance plan and the engine's imbalance
    trigger share.  Zero for page kinds (no binding to rebalance)."""
    import jax.numpy as jnp
    scfg = shard_config(cfg, num_shards)
    if kind != "chunk":
        return jnp.zeros(num_shards, jnp.int32)
    lay = arena.layout(scfg, kind, family)
    C = scfg.num_classes
    out = []
    for s in range(num_shards):
        _, _, meta = arena.unpack(lay, arena.Arena(mem[s], ctl[s]))
        cc = jnp.clip(meta.chunk_class, 0, C - 1)
        ppc = jnp.right_shift(scfg.max_pages_per_chunk, cc)
        pw = jnp.left_shift(scfg.page_words(0), cc)
        live = jnp.where(meta.chunk_class >= 0,
                         (ppc - meta.free_count) * pw, 0)
        out.append(jnp.sum(live))
    return jnp.stack(out).astype(jnp.int32)


def rebalance_plan_math(cfg: HeapConfig, num_shards: int, kind: str,
                        family: str, mem, ctl, *, max_moves: int):
    """Cross-shard relocation plan (DESIGN.md §10): move live extents
    from the most-loaded shard's **sparsest** chunks into free slots of
    the least-loaded shard's **densest** bound chunks, class by class,
    until the load gap would close (half the difference) or the table
    fills.  Returns ``(src, dst, sizes)`` GLOBAL word offsets, −1
    padded — the same forwarding-table format as the in-shard plan,
    executed by the same ``transactions.sharded_migrate`` wave (which
    rebuilds every shard, so the donor retires its emptied chunks)."""
    import jax
    import jax.numpy as jnp
    from repro.core import defrag as _defrag

    if kind != "chunk":
        f = _defrag.empty_forwarding(max_moves)
        return f.src, f.dst, f.sizes
    scfg = shard_config(cfg, num_shards)
    lay = arena.layout(scfg, kind, family)
    C = scfg.num_classes
    nc = scfg.num_chunks
    wpc = scfg.words_per_chunk
    Ws = scfg.total_words
    maxbits = scfg.bitmap_words_per_chunk * 32
    ids = jnp.arange(nc, dtype=jnp.int32)
    bitpos = jnp.arange(maxbits, dtype=jnp.int32)

    live_w = shard_live_words(cfg, num_shards, kind, family, mem, ctl)
    donor = jnp.argmax(live_w).astype(jnp.int32)
    recv = jnp.argmin(live_w).astype(jnp.int32)
    budget_words = jnp.maximum(
        (jnp.max(live_w) - jnp.min(live_w)) // 2, 0)

    def views_of(s):
        _, ctx, meta = arena.unpack(lay, arena.Arena(
            jax.lax.dynamic_index_in_dim(mem, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ctl, s, 0, keepdims=False)))
        return ctx, meta

    (_, dm), (rctx, rm) = views_of(donor), views_of(recv)
    d_occ = _defrag._occupancy_bits(dm.bitmap)
    r_occ = _defrag._occupancy_bits(rm.bitmap)
    # the receiver accepts moves into free slots of its bound chunks
    # AND into chunks sitting in its pool (the execute step claims
    # those on insert, exactly like alloc's from-pool path)
    r_pool = _defrag._pool_members(scfg, rctx.pool)

    src = jnp.full(max_moves, -1, jnp.int32)
    dst = jnp.full(max_moves, -1, jnp.int32)
    sz = jnp.zeros(max_moves, jnp.int32)
    base = jnp.int32(0)
    k = jnp.arange(max_moves, dtype=jnp.int32)
    for c in range(C):
        ppc = scfg.pages_per_chunk(c)
        pw = scfg.page_words(c)
        in_range = bitpos[None, :] < ppc
        d_bound = dm.chunk_class == c
        r_bound = rm.chunk_class == c
        d_live = jnp.where(d_bound, ppc - dm.free_count, 0)
        r_live = jnp.where(r_bound, ppc - rm.free_count, 0)
        # donor pages from its sparsest chunks first (so they empty and
        # retire in this wave); receiver slots densest-bound-first,
        # then pool chunks (claimed at insert) in id order
        d_key = jnp.where(d_bound, d_live * nc + ids,
                          (ppc + 1) * nc + ids)
        r_key = jnp.where(r_bound, (ppc - r_live) * nc + ids,
                          jnp.where(r_pool, (ppc + 1) * nc + ids,
                                    (ppc + 2) * nc + ids))
        d_order = jnp.argsort(d_key)
        r_order = jnp.argsort(r_key)
        src_bits = d_occ & d_bound[:, None] & in_range
        dst_bits = (((~r_occ) & r_bound[:, None])
                    | r_pool[:, None]) & in_range
        avail = jnp.minimum(jnp.sum(src_bits.astype(jnp.int32)),
                            jnp.sum(dst_bits.astype(jnp.int32)))
        budget = jnp.clip(jnp.minimum(budget_words // pw, avail),
                          0, max_moves - base)
        off_of = ids[:, None] * wpc + bitpos[None, :] * pw
        s_off, cnt = _defrag._take_bits(src_bits, d_order, budget,
                                        off_of, max_moves)
        d_off, _ = _defrag._take_bits(dst_bits, r_order, budget,
                                      off_of, max_moves)
        pos = jnp.where(k < cnt, base + k, max_moves)
        src = src.at[pos].set(s_off + donor * Ws, mode="drop")
        dst = dst.at[pos].set(d_off + recv * Ws, mode="drop")
        sz = sz.at[pos].set(scfg.page_bytes(c), mode="drop")
        base = base + cnt
        budget_words = budget_words - cnt * pw
        # a pool chunk claimed by this class must not be offered to a
        # later class in the same wave (one chunk, one page size)
        used = jnp.zeros(nc + 1, bool).at[
            jnp.where((k < cnt) & (d_off >= 0), d_off // wpc, nc)].set(
            True, mode="drop")
        r_pool = r_pool & ~used[:nc]
    # a shard never rebalances onto itself (equal loads → zero budget,
    # but pin it structurally too)
    noop = donor == recv
    src = jnp.where(noop, -1, src)
    dst = jnp.where(noop, -1, dst)
    sz = jnp.where(noop, 0, sz)
    return src, dst, sz


def split_regions(slay: ShardLayout, mem):
    """``mem`` (S, mem_words) as {region: (S, region words)} stacked
    per-shard views (zero-cost static slices — the sharded blocked
    lowering's plumbing, mirroring ``arena.split``)."""
    S = slay.num_shards
    return {r.name: jax.lax.slice(mem, (0, r.offset), (S, r.end))
            for r in slay.shard.regions}


def join_regions(slay: ShardLayout, parts):
    """Inverse of :func:`split_regions`."""
    S = slay.num_shards
    return jnp.concatenate([parts[r.name].reshape(S, -1)
                            for r in slay.shard.regions], axis=1)
