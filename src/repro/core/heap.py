"""Heap layout for the Ouroboros-TPU dynamic memory manager.

The paper (Standish 2025, porting Ouroboros [Winter et al. ICS'20])
pre-allocates a block of device memory (the *heap*), divides it into
equal-sized *chunks*, and serves allocation requests as *pages* carved
out of chunks.  Per-size-class queues hand out free pages (or chunks
with free pages).

Everything here is static layout math: the heap itself is a flat int32
word array (1 word = 4 bytes), so offsets fit int32 and the virtualized
queue variants can store their own queue segments *inside* heap chunks —
the defining self-referential trait of Ouroboros.
"""
from __future__ import annotations

import dataclasses
import math

WORD_BYTES = 4


def _log2i(x: int) -> int:
    if x <= 0 or x & (x - 1):
        raise ValueError(f"expected positive power of two, got {x}")
    return x.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class HeapConfig:
    """Static configuration of the device heap.

    Defaults give an 8 MiB heap with 8 KiB chunks and size classes
    16 B .. 8 KiB (ten classes), mirroring the paper's benchmark range
    of allocation sizes (figs. 1-6 sweep 4 B .. 8 KiB).  The paper
    itself notes it shrank the heap to fit the author's device; tests
    shrink further for speed — the layout math is scale-free.
    """

    total_bytes: int = 8 << 20
    chunk_bytes: int = 8 << 10
    min_page_bytes: int = 16
    # Ring capacity head-room factor for the non-virtualized queues.
    # Virtualized variants size their directories from the same bound.
    max_alloc_batch: int = 8192

    def __post_init__(self):
        _log2i(self.chunk_bytes)
        _log2i(self.min_page_bytes)
        if self.total_bytes % self.chunk_bytes:
            raise ValueError("total_bytes must be a multiple of chunk_bytes")
        if self.min_page_bytes < WORD_BYTES:
            raise ValueError("min page must hold at least one word")

    # ---- derived layout ----------------------------------------------------
    @property
    def num_chunks(self) -> int:
        return self.total_bytes // self.chunk_bytes

    @property
    def words_per_chunk(self) -> int:
        return self.chunk_bytes // WORD_BYTES

    @property
    def total_words(self) -> int:
        return self.total_bytes // WORD_BYTES

    @property
    def num_classes(self) -> int:
        """Size classes are powers of two: min_page .. chunk_bytes."""
        return _log2i(self.chunk_bytes) - _log2i(self.min_page_bytes) + 1

    def page_bytes(self, c: int) -> int:
        return self.min_page_bytes << c

    def page_words(self, c: int) -> int:
        return self.page_bytes(c) // WORD_BYTES

    def pages_per_chunk(self, c: int) -> int:
        return self.chunk_bytes // self.page_bytes(c)

    @property
    def max_pages_per_chunk(self) -> int:
        return self.pages_per_chunk(0)

    @property
    def bitmap_words_per_chunk(self) -> int:
        """Occupancy bitmap words (32 pages tracked per uint32 word)."""
        return max(1, self.max_pages_per_chunk // 32)

    def size_to_class(self, size_bytes: int) -> int:
        """Smallest size class whose page holds ``size_bytes`` (host math)."""
        size_bytes = max(size_bytes, self.min_page_bytes)
        c = math.ceil(math.log2(size_bytes)) - _log2i(self.min_page_bytes)
        if c >= self.num_classes:
            raise ValueError(
                f"allocation of {size_bytes} B exceeds chunk size "
                f"{self.chunk_bytes} B")
        return c

    def chunk_word_base(self, chunk_id: int) -> int:
        return chunk_id * self.words_per_chunk

    @property
    def data_chunks_per_class(self) -> int:
        """Even chunk split for page allocators, with one class-share
        held back for virtualized queue segments (their worst-case need
        is ~share/2 chunks)."""
        return max(1, self.num_chunks // (self.num_classes + 1))

    def slots_per_segment(self, family: str) -> int:
        """Queue items one heap-chunk segment holds.  vl segments
        reserve word 0 for the next pointer; ring queues don't live in
        chunks but the bound keeps arena layouts uniform."""
        return self.words_per_chunk - (1 if family == "vl" else 0)


def size_to_class_device(cfg: HeapConfig, sizes):
    """Vectorized size→class mapping (device math, jit-safe).

    ``sizes`` in bytes; returns int32 class ids.  Sizes above the chunk
    size map to ``num_classes`` (an invalid class — callers treat it as
    an allocation failure, matching the GPU original which returns
    nullptr for over-large requests).  Negative sizes — which is what a
    >2 GiB request looks like after the int32 cast — are over-large by
    definition and map to ``num_classes`` too, never to a small class.
    """
    import jax.numpy as jnp

    raw = sizes.astype(jnp.int32)
    sizes = jnp.maximum(raw, cfg.min_page_bytes)
    # ceil(log2(s)) via bit twiddling on ints: position of MSB of (s-1)+1.
    bits = 32 - _clz32(sizes - 1)
    c = bits - _log2i(cfg.min_page_bytes)
    return jnp.where((raw < 0) | (sizes > cfg.chunk_bytes),
                     cfg.num_classes, c).astype(jnp.int32)


def _clz32(x):
    """Count leading zeros of each int32 (x >= 0); clz(0) = 32."""
    import jax.numpy as jnp

    x = x.astype(jnp.uint32)
    n = jnp.zeros_like(x)
    for shift in (16, 8, 4, 2, 1):
        mask = x <= (jnp.uint32(0xFFFFFFFF) >> shift)
        n = jnp.where(mask, n + shift, n)
        x = jnp.where(mask, x << shift, x)
    return jnp.where(x == 0, jnp.uint32(32), n).astype(jnp.int32)
