"""The three Ouroboros queue families, as functional JAX state machines.

Ouroboros' contribution is the *virtualized* queue: queue storage is
itself composed of heap chunks, so queue memory scales with occupancy
instead of worst case.  The paper benchmarks three families × two item
kinds (pages / chunks):

- ``ring``  — plain pre-allocated ring buffer (the ``p``/``c`` drivers)
- ``va``    — virtualized *array* queue: a ring **directory** of chunk
              ids; virtual slot ``v`` lives in heap chunk
              ``dir[v // slots_per_seg]`` (figs. 3, 5)
- ``vl``    — virtualized *linked-list* queue: segments chained through
              a next-pointer stored in slot 0 of each segment chunk
              (figs. 4, 6)

GPU Ouroboros mutates front/back with per-thread atomics; here a whole
batch of requests is applied as one transaction: every request carries a
class id and an intra-class ``rank`` (from ``groups.masked_rank``), the
per-class counters advance once by the aggregated count, and slot
addresses are computed as ``(counter + rank) % capacity``.  See
DESIGN.md §2 for the mechanism mapping.

All ``bulk_*`` functions are jit-safe and fixed-shape: the number of
queue *segments* touched per transaction is bounded statically by
``ceil(N / slots_per_seg) + 1`` where N is the request vector width.

Every bulk function takes a trailing ``backend`` argument: ``"jnp"``
(default) is the reference gather/scatter path, ``"pallas"`` routes
ring transactions through the piecewise PR-1 kernels in
kernels/alloc_txn.py.  Production transactions no longer thread
through that flag: core/transactions.py runs this module's jnp path as
the body of BOTH backends — directly as the oracle, and inside the
single fused arena kernel for ``backend="pallas"`` (DESIGN.md §4, §7).
State arrives as zero-cost views unpacked from the flat arena
(core/arena.py), where queue rings, directories, and counters live at
fixed word offsets.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core import groups
from repro.core.heap import HeapConfig

# A Python int (not a jnp scalar): module-level jnp constants would be
# captured as jaxpr consts inside the fused arena kernels, which Pallas
# kernel tracing rejects; int literals weaken to int32 everywhere used.
NULL = -1


class RingState(NamedTuple):
    store: Any  # (C, cap) int32
    front: Any  # (C,) int32, monotonically increasing virtual index
    back: Any   # (C,) int32


class AllocCtx(NamedTuple):
    """Shared mutable context threaded through every queue transaction.

    ``heap``  — the flat word array; virtualized queues store segments here.
    ``pool``  — ring of free chunk ids (the base allocator every
                virtualized queue grows/shrinks against).
    """
    heap: Any  # (total_words,) int32
    pool: RingState  # single-class ring of chunk ids


class VirtState(NamedTuple):
    """State for both virtualized families.

    ``va``: ``directory`` is a (C, max_segs) ring of segment chunk ids;
    ``head``/``tail`` are unused (kept NULL).
    ``vl``: ``directory`` is unused; ``head``/``tail`` are the chunk ids
    of the front/back segments and chaining lives in heap slot 0.
    """
    directory: Any  # (C, max_segs) int32
    head: Any       # (C,) int32 chunk ids
    tail: Any       # (C,) int32 chunk ids
    front: Any      # (C,) int32
    back: Any       # (C,) int32


# --------------------------------------------------------------------------
# plain ring family
# --------------------------------------------------------------------------

def ring_init(num_classes: int, capacity: int) -> RingState:
    return RingState(
        store=jnp.full((num_classes, capacity), NULL, jnp.int32),
        front=jnp.zeros(num_classes, jnp.int32),
        back=jnp.zeros(num_classes, jnp.int32),
    )


def ring_count(q: RingState):
    return q.back - q.front


def ring_bulk_dequeue(cfg: HeapConfig, q: RingState, ctx: AllocCtx,
                      cls, rank, mask, backend: str = "jnp"):
    """``backend="pallas"`` routes through the fused transaction kernel
    (kernels/alloc_txn.ring_txn_pop), which recomputes the rank
    in-kernel — every call site's ``rank`` equals
    ``groups.masked_rank(cls, mask)``, so the paths are bit-identical
    (asserted by tests/test_alloc_txn_parity.py)."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        vals, new_front = kops.ring_txn_pop(q.store, q.front, q.back,
                                            cls, mask, limit=False)
        return q._replace(front=new_front), ctx, vals
    cap = q.store.shape[1]
    num_classes = q.store.shape[0]
    counts = groups.segment_counts(cls, mask, num_classes)
    pos = (q.front[cls % num_classes] + rank) % cap
    vals = q.store.at[cls % num_classes, pos].get(mode="fill", fill_value=-1)
    vals = jnp.where(mask, vals, NULL)
    return q._replace(front=q.front + counts), ctx, vals


def ring_bulk_enqueue(cfg: HeapConfig, q: RingState, ctx: AllocCtx,
                      cls, rank, vals, mask, backend: str = "jnp"):
    if backend == "pallas":
        from repro.kernels import ops as kops
        store, new_back = kops.ring_txn_push(q.store, q.back, cls, vals,
                                             mask)
        return q._replace(store=store, back=new_back), ctx
    cap = q.store.shape[1]
    num_classes = q.store.shape[0]
    counts = groups.segment_counts(cls, mask, num_classes)
    cls_s = jnp.where(mask, cls, num_classes)  # OOB row → dropped
    pos = (q.back[cls % num_classes] + rank) % cap
    store = q.store.at[cls_s, pos].set(vals, mode="drop")
    return q._replace(store=store, back=q.back + counts), ctx


# --------------------------------------------------------------------------
# chunk pool helpers (single-class ring of free chunk ids)
# --------------------------------------------------------------------------

def pool_init(cfg: HeapConfig) -> RingState:
    """All heap chunks start free, queued FIFO in the pool."""
    ids = jnp.arange(cfg.num_chunks, dtype=jnp.int32)[None, :]
    return RingState(store=ids,
                     front=jnp.zeros(1, jnp.int32),
                     back=jnp.full(1, cfg.num_chunks, jnp.int32))


def pool_count(pool: RingState):
    return (pool.back - pool.front)[0]


def pool_dequeue(cfg: HeapConfig, pool: RingState, mask,
                 backend: str = "jnp"):
    """Pop one chunk id per active lane (flat mask)."""
    rank = groups.masked_prefix_sum(jnp.ones_like(mask, jnp.int32), mask)
    cls = jnp.zeros(mask.shape[0], jnp.int32)
    pool, _, chunks = ring_bulk_dequeue(
        cfg, pool, None, cls, rank, mask, backend)
    return pool, chunks


def pool_enqueue(cfg: HeapConfig, pool: RingState, chunks, mask,
                 backend: str = "jnp"):
    rank = groups.masked_prefix_sum(jnp.ones_like(mask, jnp.int32), mask)
    cls = jnp.zeros(mask.shape[0], jnp.int32)
    pool, _ = ring_bulk_enqueue(cfg, pool, None, cls, rank, chunks, mask,
                                backend)
    return pool


# --------------------------------------------------------------------------
# shared virtualized-queue math
# --------------------------------------------------------------------------

def _slots_per_seg(cfg: HeapConfig, family: str) -> int:
    # vl segments reserve word 0 for the next pointer; the math lives
    # on HeapConfig so core/arena.py sizes directories identically.
    return cfg.slots_per_segment(family)


def _grow_counts(counts, back, spc):
    """Segments to append so slots [back, back+counts) plus the next
    insertion point all live in allocated segments."""
    return (back + counts) // spc - back // spc


def _shrink_counts(counts, front, spc):
    """Segments fully consumed once front advances by ``counts``."""
    return (front + counts) // spc - front // spc


def _grid_mask(n_per_class, m):
    """(C, m) mask: entry [c, j] active iff j < n_per_class[c]."""
    return jnp.arange(m, dtype=jnp.int32)[None, :] < n_per_class[:, None]


def virt_init(cfg: HeapConfig, ctx: AllocCtx, num_classes: int,
              max_items_per_class: int, family: str):
    """Allocate one empty segment per class from the pool."""
    spc = _slots_per_seg(cfg, family)
    max_segs = max_items_per_class // spc + 2
    mask = jnp.ones(num_classes, bool)
    pool, seg0 = pool_dequeue(cfg, ctx.pool, mask)
    heap = ctx.heap
    if family == "vl":
        heap = heap.at[seg0 * cfg.words_per_chunk].set(NULL)
        directory = jnp.full((num_classes, max_segs), NULL, jnp.int32)
    else:
        directory = jnp.full((num_classes, max_segs), NULL, jnp.int32)
        directory = directory.at[:, 0].set(seg0)
    # head/tail must be distinct buffers: donation rejects the same
    # buffer appearing twice in a donated pytree.
    q = VirtState(directory=directory, head=seg0, tail=seg0 + 0,
                  front=jnp.zeros(num_classes, jnp.int32),
                  back=jnp.zeros(num_classes, jnp.int32))
    return q, AllocCtx(heap=heap, pool=pool)


def virt_count(q: VirtState):
    return q.back - q.front


# --------------------------------------------------------------------------
# virtualized ARRAY queue (directory-indexed)  — figs. 3 & 5
# --------------------------------------------------------------------------

def va_bulk_enqueue(cfg: HeapConfig, q: VirtState, ctx: AllocCtx,
                    cls, rank, vals, mask, backend: str = "jnp"):
    spc = _slots_per_seg(cfg, "va")
    wpc = cfg.words_per_chunk
    C, max_segs = q.directory.shape
    n = cls.shape[0]
    m = n // spc + 1  # static bound on new segments per class
    counts = groups.segment_counts(cls, mask, C)

    # 1. grow: append segments so the whole write window is backed.
    n_new = _grow_counts(counts, q.back, spc)
    grid = _grid_mask(n_new, m).reshape(-1)
    pool, new_chunks = pool_dequeue(cfg, ctx.pool, grid, backend)
    new_chunks = new_chunks.reshape(C, m)
    seg_back = q.back // spc
    dir_pos = (seg_back[:, None] + 1 + jnp.arange(m, dtype=jnp.int32)[None, :]
               ) % max_segs
    row = jnp.where(grid.reshape(C, m),
                    jnp.arange(C, dtype=jnp.int32)[:, None], C)
    directory = q.directory.at[row, dir_pos].set(new_chunks, mode="drop")

    # 2. write values through the (updated) directory.
    v = q.back[cls % C] + rank
    seg_chunk = directory.at[cls % C, (v // spc) % max_segs].get(
        mode="fill", fill_value=0)
    word = seg_chunk * wpc + v % spc
    heap = ctx.heap.at[jnp.where(mask, word, ctx.heap.shape[0])].set(
        vals, mode="drop")

    q = q._replace(directory=directory, back=q.back + counts)
    return q, AllocCtx(heap=heap, pool=pool)


def va_bulk_dequeue(cfg: HeapConfig, q: VirtState, ctx: AllocCtx,
                    cls, rank, mask, backend: str = "jnp"):
    spc = _slots_per_seg(cfg, "va")
    wpc = cfg.words_per_chunk
    C, max_segs = q.directory.shape
    n = cls.shape[0]
    m = n // spc + 1
    counts = groups.segment_counts(cls, mask, C)

    # 1. gather values.
    v = q.front[cls % C] + rank
    seg_chunk = q.directory.at[cls % C, (v // spc) % max_segs].get(
        mode="fill", fill_value=0)
    word = seg_chunk * wpc + v % spc
    vals = ctx.heap.at[word].get(mode="fill", fill_value=-1)
    vals = jnp.where(mask, vals, NULL)

    # 2. shrink: return fully-consumed segments to the pool.
    n_free = _shrink_counts(counts, q.front, spc)
    grid = _grid_mask(n_free, m)
    seg_front = q.front // spc
    dir_pos = (seg_front[:, None] + jnp.arange(m, dtype=jnp.int32)[None, :]
               ) % max_segs
    freed = q.directory[jnp.arange(C)[:, None], dir_pos]
    pool = pool_enqueue(cfg, ctx.pool, freed.reshape(-1), grid.reshape(-1),
                        backend)

    q = q._replace(front=q.front + counts)
    return q, AllocCtx(heap=ctx.heap, pool=pool), vals


# --------------------------------------------------------------------------
# virtualized LIST queue (next-pointer chained)  — figs. 4 & 6
# --------------------------------------------------------------------------

def vl_bulk_enqueue(cfg: HeapConfig, q: VirtState, ctx: AllocCtx,
                    cls, rank, vals, mask, backend: str = "jnp"):
    spc = _slots_per_seg(cfg, "vl")
    wpc = cfg.words_per_chunk
    C = q.front.shape[0]
    n = cls.shape[0]
    m = n // spc + 1
    counts = groups.segment_counts(cls, mask, C)
    heap = ctx.heap
    W = heap.shape[0]

    # 1. grow: pop new segment chunks and chain them after the tail.
    n_new = _grow_counts(counts, q.back, spc)
    grid = _grid_mask(n_new, m)
    pool, new_chunks = pool_dequeue(cfg, ctx.pool, grid.reshape(-1),
                                    backend)
    new_chunks = new_chunks.reshape(C, m)
    # terminate every new segment, then link prev -> new (j = 0 links
    # from the current tail).
    heap = heap.at[jnp.where(grid, new_chunks * wpc, W)].set(
        NULL, mode="drop")
    for j in range(m):
        prev = q.tail if j == 0 else new_chunks[:, j - 1]
        ok = grid[:, j]
        heap = heap.at[jnp.where(ok, prev * wpc, W)].set(
            new_chunks[:, j], mode="drop")

    # 2. write values: segment 0 relative to back-seg is the tail chunk,
    # segment j>0 is new_chunks[:, j-1].
    v = q.back[cls % C] + rank
    seg_rel = v // spc - q.back[cls % C] // spc  # 0..m
    seg_chunk = jnp.where(
        seg_rel == 0, q.tail[cls % C],
        new_chunks.at[cls % C, seg_rel - 1].get(mode="fill", fill_value=0))
    word = seg_chunk * wpc + 1 + v % spc
    heap = heap.at[jnp.where(mask, word, W)].set(vals, mode="drop")

    last = jnp.maximum(n_new - 1, 0)
    tail = jnp.where(n_new > 0, new_chunks[jnp.arange(C), last], q.tail)
    q = q._replace(tail=tail, back=q.back + counts)
    return q, AllocCtx(heap=heap, pool=pool)


def vl_bulk_dequeue(cfg: HeapConfig, q: VirtState, ctx: AllocCtx,
                    cls, rank, mask, backend: str = "jnp"):
    spc = _slots_per_seg(cfg, "vl")
    wpc = cfg.words_per_chunk
    C = q.front.shape[0]
    n = cls.shape[0]
    m = n // spc + 1
    counts = groups.segment_counts(cls, mask, C)
    heap = ctx.heap

    # 1. walk the chain from the head segment (static m+1 hops).
    chain = [q.head]
    for _ in range(m):
        nxt = heap.at[chain[-1] * wpc].get(mode="fill", fill_value=-1)
        chain.append(jnp.where(chain[-1] >= 0, nxt, NULL))
    chain = jnp.stack(chain, axis=1)  # (C, m+1)

    # 2. gather values.
    v = q.front[cls % C] + rank
    seg_rel = v // spc - q.front[cls % C] // spc
    seg_chunk = chain.at[cls % C, seg_rel].get(mode="fill", fill_value=0)
    word = seg_chunk * wpc + 1 + v % spc
    vals = heap.at[word].get(mode="fill", fill_value=-1)
    vals = jnp.where(mask, vals, NULL)

    # 3. shrink: fully-consumed leading segments go back to the pool.
    n_free = _shrink_counts(counts, q.front, spc)
    grid = _grid_mask(n_free, m)
    freed = chain[:, :m]
    pool = pool_enqueue(cfg, ctx.pool, freed.reshape(-1), grid.reshape(-1),
                        backend)
    head = chain[jnp.arange(C), n_free]

    q = q._replace(head=head, front=q.front + counts)
    return q, AllocCtx(heap=heap, pool=pool), vals


# --------------------------------------------------------------------------
# family dispatch table
# --------------------------------------------------------------------------

class QueueFamily(NamedTuple):
    name: str
    count: Any
    bulk_dequeue: Any
    bulk_enqueue: Any


FAMILIES = {
    "ring": QueueFamily("ring", ring_count, ring_bulk_dequeue,
                        ring_bulk_enqueue),
    "va": QueueFamily("va", virt_count, va_bulk_dequeue, va_bulk_enqueue),
    "vl": QueueFamily("vl", virt_count, vl_bulk_dequeue, vl_bulk_enqueue),
}
