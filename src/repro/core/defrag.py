"""Live defragmentation: on-device page migration with a plan/execute split.

`transactions.compact` (DESIGN.md §5b) releases sticky chunk→class
bindings but never moves a live word, so a long-running heap slowly
strands physical pages: chunks stay bound while only a few of their
pages are live, the pool drains, and eventually a request fails even
though most of the heap is free.  This module is the true defrag pass —
the first subsystem where the allocator rewrites its own heap:

``plan``     a pure-jnp **relocation plan** from arena state: for each
             size class, rank the bound chunks densest-first, keep the
             minimal prefix that can hold every live page (the
             *receivers*), and move every live page of the remaining
             *donor* chunks into the receivers' free slots — sources
             ordered (chunk-rank, page) ascending, destinations
             likewise, k-th source paired with k-th destination.  The
             plan is a fixed-width **forwarding table**
             ``(src, dst, sizes)`` of old→new word offsets (−1 padded),
             shared verbatim by every backend (like
             ``shards.home_shards``) so execution can never diverge.

``migrate``  the **execute** step ``(mem, ctl, plan) → (mem', ctl')``:
             copy each extent's heap words, flip its bitmap bits, move
             the free counts, then run the class-major rebuild — unbind
             fully-free chunks, re-prime the pool with them, and
             rebuild each class queue (ring row / directory / vl chain)
             from the surviving live chunks.  An empty plan degenerates
             to exactly a ``compact``-style rebuild.  This math is the
             jnp oracle AND the body of the whole-lowering kernel
             (kernels/defrag_txn.py); the region-blocked lowering
             re-expresses it per class under the §8 discipline, and a
             wave is ONE ``pallas_call`` under both (DESIGN.md §10,
             tests/test_defrag.py).

The sharded execute (``sharded_migrate_math``) runs the same moves as a
two-phase (phase, shard) schedule — extract every source shard's pages
into a carry buffer, then insert + rebuild every shard — so ONE wave
also covers **cross-shard rebalancing**: ``shards.rebalance_plan_math``
emits moves from the most- to the least-loaded shard and the very same
kernel executes them.

Defragmentation applies to chunk kinds only (page kinds carve their
inventory at init and never bind chunks); page-kind plans are empty and
their waves are no-ops.

Plans are inspectable without running anything:

>>> import jax.numpy as jnp
>>> from repro.core import HeapConfig, defrag, transactions
>>> cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
...                  min_page_bytes=16)
>>> st = transactions.init(cfg, "chunk", "ring")
>>> ones = jnp.ones(8, bool)
>>> sizes = jnp.full(8, 16, jnp.int32)
>>> st, offs = transactions.alloc(cfg, "chunk", "ring", st, sizes, ones)
>>> src, dst, sz = defrag.plan_math(cfg, "chunk", "ring", st.mem,
...                                 st.ctl, max_moves=16)
>>> int((src >= 0).sum())          # dense heap: nothing to migrate
0
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import arena, chunk_alloc, groups, queues
from repro.core.heap import HeapConfig, size_to_class_device

# Default forwarding-table width: enough for every realistic wave on
# the serving heap; callers needing a bigger single wave pass an
# explicit max_moves (the bound is static — it shapes the kernel).
DEFAULT_MAX_MOVES = 128


class Forwarding(NamedTuple):
    """One wave's old→new relocation table (−1-padded lanes are no-ops).

    ``src``/``dst`` are heap word offsets (GLOBAL offsets for sharded
    arenas), ``sizes`` the extent sizes in bytes (the page size of the
    extent's class) — exactly the ``(offsets, sizes)`` vocabulary of
    ``alloc``/``free``, so callers remap their references with
    :func:`forward_offsets` / ``kv_cache.apply_forwarding``.
    """
    src: Any    # (M,) int32
    dst: Any    # (M,) int32
    sizes: Any  # (M,) int32


def empty_forwarding(max_moves: int = 0) -> Forwarding:
    return Forwarding(src=jnp.full(max_moves, -1, jnp.int32),
                      dst=jnp.full(max_moves, -1, jnp.int32),
                      sizes=jnp.zeros(max_moves, jnp.int32))


def forward_offsets(fwd: Forwarding, offsets_words):
    """Remap word offsets through the forwarding table (offsets not in
    the table pass through unchanged, including −1 lanes)."""
    src = jnp.where(fwd.src >= 0, fwd.src, jnp.int32(-2))
    hit = offsets_words[:, None] == src[None, :]
    new = jnp.sum(jnp.where(hit, fwd.dst[None, :], 0), axis=1)
    return jnp.where(hit.any(axis=1), new, offsets_words)


# --------------------------------------------------------------------------
# plan: pick live extents in the sparsest chunks, assign dense targets
# --------------------------------------------------------------------------

def _occupancy_bits(bitmap):
    """(nc, bw) uint32 occupancy → (nc, bw·32) bool, bit order LSB-first
    (the layout ``chunk_alloc._expand_bitmap`` reads)."""
    nc, bw = bitmap.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmap[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(nc, bw * 32).astype(bool)


def _take_bits(bits, order, limit, off_of_bit, max_moves: int):
    """The first ``limit`` set bits of ``bits``, visiting chunks in
    ``order`` and pages ascending within each chunk; returns their word
    offsets scattered to positions [0, count) of a (max_moves,) array
    (−1 padded) plus the count."""
    b = bits[order].reshape(-1)
    o = off_of_bit[order].reshape(-1)
    bi = b.astype(jnp.int32)
    ordinal = jnp.cumsum(bi) - bi
    take = b & (ordinal < limit)
    out = jnp.full(max_moves, -1, jnp.int32).at[
        jnp.where(take, ordinal, max_moves)].set(o, mode="drop")
    return out, jnp.minimum(jnp.sum(bi), limit)


def plan_math(cfg: HeapConfig, kind: str, family: str, mem, ctl, *,
              max_moves: int = DEFAULT_MAX_MOVES):
    """Relocation plan for one arena (the jnp oracle — every backend
    executes this exact table).  Returns ``(src, dst, sizes)`` local
    word offsets, −1 padded to ``max_moves``.

    Per class: chunks ranked densest-first (live pages descending, id
    ascending); the minimal receiver prefix that can hold all live
    pages keeps them, every other bound chunk donates.  Any prefix of
    the table is a valid (smaller) wave — destinations are slots that
    were free *before* the wave and never slots another move vacates —
    so ``max_moves`` truncation is safe and later waves converge."""
    if kind != "chunk":
        f = empty_forwarding(max_moves)
        return f.src, f.dst, f.sizes
    lay = arena.layout(cfg, kind, family)
    _, _, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    nc = cfg.num_chunks
    wpc = cfg.words_per_chunk
    maxbits = cfg.bitmap_words_per_chunk * 32
    C = cfg.num_classes
    ids = jnp.arange(nc, dtype=jnp.int32)
    bitpos = jnp.arange(maxbits, dtype=jnp.int32)
    occ = _occupancy_bits(meta.bitmap)

    src = jnp.full(max_moves, -1, jnp.int32)
    dst = jnp.full(max_moves, -1, jnp.int32)
    sz = jnp.zeros(max_moves, jnp.int32)
    base = jnp.int32(0)
    for c in range(C):
        ppc = cfg.pages_per_chunk(c)
        pw = cfg.page_words(c)
        bound = meta.chunk_class == c
        in_range = bitpos[None, :] < ppc
        live = jnp.where(bound, ppc - meta.free_count, 0)
        need = (jnp.sum(live) + ppc - 1) // ppc
        # densest bound chunks first, unbound chunks last (unique keys)
        key = jnp.where(bound, (ppc - live) * nc + ids,
                        (ppc + 1) * nc + ids)
        order = jnp.argsort(key)
        rank = jnp.zeros(nc, jnp.int32).at[order].set(ids)
        is_recv = bound & (rank < need)
        is_donor = bound & (rank >= need)
        src_bits = occ & is_donor[:, None] & in_range
        dst_bits = (~occ) & is_recv[:, None] & in_range
        budget = jnp.clip(max_moves - base, 0,
                          jnp.sum(src_bits.astype(jnp.int32)))
        off_of = ids[:, None] * wpc + bitpos[None, :] * pw
        s_off, cnt = _take_bits(src_bits, order, budget, off_of,
                                max_moves)
        d_off, _ = _take_bits(dst_bits, order, budget, off_of,
                              max_moves)
        k = jnp.arange(max_moves, dtype=jnp.int32)
        pos = jnp.where(k < cnt, base + k, max_moves)
        src = src.at[pos].set(s_off, mode="drop")
        dst = dst.at[pos].set(d_off, mode="drop")
        sz = sz.at[pos].set(cfg.page_bytes(c), mode="drop")
        base = base + cnt
    return src, dst, sz


def sharded_plan_math(cfg: HeapConfig, num_shards: int, kind: str,
                      family: str, mem, ctl, *,
                      max_moves: int = DEFAULT_MAX_MOVES):
    """Per-shard compaction plans merged into one GLOBAL-offset table
    (shards are independent heaps, so in-shard plans compose by
    concatenation; cross-shard moves are ``shards.rebalance_plan_math``'s
    job)."""
    from repro.core import shards  # lazy: defrag <-> shards
    if kind != "chunk":
        f = empty_forwarding(max_moves)
        return f.src, f.dst, f.sizes
    scfg = shards.shard_config(cfg, num_shards)
    Ws = scfg.total_words
    src = jnp.full(max_moves, -1, jnp.int32)
    dst = jnp.full(max_moves, -1, jnp.int32)
    sz = jnp.zeros(max_moves, jnp.int32)
    base = jnp.int32(0)
    k = jnp.arange(max_moves, dtype=jnp.int32)
    for s in range(num_shards):
        s_src, s_dst, s_sz = plan_math(scfg, kind, family, mem[s],
                                       ctl[s], max_moves=max_moves)
        cnt = jnp.sum((s_src >= 0).astype(jnp.int32))
        cnt = jnp.minimum(cnt, max_moves - base)
        pos = jnp.where(k < cnt, base + k, max_moves)
        src = src.at[pos].set(s_src + s * Ws, mode="drop")
        dst = dst.at[pos].set(s_dst + s * Ws, mode="drop")
        sz = sz.at[pos].set(s_sz, mode="drop")
        base = base + cnt
    return src, dst, sz


# --------------------------------------------------------------------------
# execute: extract / insert+rebuild (the migration oracle)
# --------------------------------------------------------------------------
#
# The execute math is split so the sharded schedule can reuse it: a
# wave is extract (gather each source extent's words into its carry-
# buffer row, clear its bits, return its pages to the free counts)
# followed by insert+rebuild (write the buffered words at the
# destinations, set bits, then the class-major rebuild).  The
# single-arena migrate is the composition on one arena; the sharded
# migrate runs extract over every shard, then insert+rebuild over
# every shard (phase-major, shard-minor — the schedule both Pallas
# lowerings grid into ONE pallas_call).

def _move_lanes(cfg: HeapConfig, offsets, sizes, sel):
    C = cfg.num_classes
    cls = size_to_class_device(cfg, sizes)
    valid = sel & (offsets >= 0) & (cls < C)
    pw = jnp.left_shift(cfg.page_words(0), cls % C).astype(jnp.int32)
    return valid, pw


def extract_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
                 src, sizes, sel, buf):
    """Phase-0 of a wave on one arena: buffer the selected extents'
    heap words, clear their bitmap bits, bump their chunks' free
    counts.  Queues/ctl are untouched (the rebuild happens at insert).
    Returns ``(mem', buf')``."""
    lay = arena.layout(cfg, kind, family)
    q, ctx, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    W = cfg.total_words
    wpc = cfg.words_per_chunk
    maxw = wpc
    valid, pw = _move_lanes(cfg, src, sizes, sel)
    j = jnp.arange(maxw, dtype=jnp.int32)[None, :]
    ok = valid[:, None] & (j < pw[:, None])
    words = jnp.where(ok, src[:, None] + j, W)
    vals = ctx.heap.at[words].get(mode="fill", fill_value=0)
    buf = jnp.where(ok, vals, buf)
    chunk = jnp.where(valid, src // wpc, cfg.num_chunks)
    page = jnp.where(valid, (src % wpc) // pw, 0)
    meta = chunk_alloc._set_bits(meta, chunk, page, valid, -1)
    return arena.pack(lay, q, ctx, meta).mem, buf


def insert_rebuild_math(cfg: HeapConfig, kind: str, family: str, mem,
                        ctl, dst, sizes, sel, buf):
    """Phase-1 of a wave on one arena: write the buffered extents at
    their destinations, set their bits, then the class-major rebuild
    (unbind fully-free chunks → fresh pool → per-class queue rebuild).
    Returns ``(mem', ctl')`` — runs even for an empty selection, where
    it degenerates to the compact-style rebuild."""
    lay = arena.layout(cfg, kind, family)
    q, ctx, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    C = cfg.num_classes
    nc = cfg.num_chunks
    W = cfg.total_words
    wpc = cfg.words_per_chunk
    maxw = wpc
    valid, pw = _move_lanes(cfg, dst, sizes, sel)

    # insert the buffered words
    j = jnp.arange(maxw, dtype=jnp.int32)[None, :]
    ok = valid[:, None] & (j < pw[:, None])
    words = jnp.where(ok, dst[:, None] + j, W)
    heap = ctx.heap.at[words].set(buf, mode="drop")
    ctx = ctx._replace(heap=heap)
    chunk = jnp.where(valid, dst // wpc, nc)
    page = jnp.where(valid, (dst % wpc) // pw, 0)

    # destination chunks still unbound (cross-shard rebalance targets
    # the receiver's pool chunks) are claimed first — bitmap reset,
    # full free count, bound to the move's class — exactly alloc's
    # from-pool path; the rebuild below then keeps them out of the
    # fresh pool because they are bound now.
    cls = size_to_class_device(cfg, sizes)
    claimed = jnp.zeros(nc, bool).at[chunk].set(
        True, mode="drop") & (meta.chunk_class < 0)
    ppc_move = jnp.right_shift(cfg.max_pages_per_chunk,
                               jnp.clip(cls, 0, C - 1))
    bitmap = jnp.where(claimed[:, None], jnp.uint32(0), meta.bitmap)
    fc = meta.free_count.at[jnp.where(valid & claimed[chunk % nc],
                                      chunk, nc)].set(
        ppc_move, mode="drop")
    cc0 = meta.chunk_class.at[jnp.where(valid & claimed[chunk % nc],
                                        chunk, nc)].set(
        cls, mode="drop")
    meta = meta._replace(bitmap=bitmap, free_count=fc, chunk_class=cc0)
    meta = chunk_alloc._set_bits(meta, chunk, page, valid, +1)

    # unbind fully-free chunks, re-prime the pool with every unbound id
    maxppc = cfg.max_pages_per_chunk
    cc = meta.chunk_class
    full_count = jnp.right_shift(maxppc, jnp.clip(cc, 0, C - 1))
    fully_free = (cc >= 0) & (meta.free_count == full_count)
    cc = jnp.where(fully_free, -1, cc)
    meta = meta._replace(chunk_class=cc)
    ids = jnp.arange(nc, dtype=jnp.int32)
    unbound = cc < 0
    rank = groups.masked_prefix_sum(jnp.ones(nc, jnp.int32), unbound)
    pool, _ = queues.ring_bulk_enqueue(
        cfg, queues.ring_init(1, nc), None, jnp.zeros(nc, jnp.int32),
        rank, ids, unbound)
    ctx = queues.AllocCtx(heap=ctx.heap, pool=pool)

    # class-major queue rebuild (matches the blocked lowering's grid
    # order step for step — every pool pop happens in class order)
    fam = queues.FAMILIES[family]
    if family == "ring":
        q = queues.ring_init(C, lay.queue_capacity)
    else:
        q = queues.VirtState(
            directory=jnp.full((C, lay.max_segs), queues.NULL, jnp.int32),
            head=jnp.full(C, queues.NULL, jnp.int32),
            tail=jnp.full(C, queues.NULL, jnp.int32),
            front=jnp.zeros(C, jnp.int32), back=jnp.zeros(C, jnp.int32))
    for c in range(C):
        live_c = (cc == c) & (meta.free_count > 0)
        if family != "ring":
            # one fresh segment per class, popped in class order
            pool2, seg0 = queues.pool_dequeue(cfg, ctx.pool,
                                              jnp.ones(1, bool))
            ctx = ctx._replace(pool=pool2)
            s0 = seg0[0]
            if family == "vl":
                w0 = s0 * wpc
                heap = ctx.heap.at[jnp.where((w0 >= 0) & (w0 < W),
                                             w0, W)].set(
                    queues.NULL, mode="drop")
                ctx = ctx._replace(heap=heap)
            else:
                q = q._replace(directory=q.directory.at[c, 0].set(s0))
            q = q._replace(head=q.head.at[c].set(s0),
                           tail=q.tail.at[c].set(s0))
        rk = groups.masked_prefix_sum(jnp.ones(nc, jnp.int32), live_c)
        q, ctx = fam.bulk_enqueue(cfg, q, ctx, jnp.full(nc, c, jnp.int32),
                                  rk, ids, live_c)
    # a defrag wave is not allocator traffic: the ctl telemetry region
    # (DESIGN.md §14) carries through unchanged — matching the blocked
    # kernels, which stage the full ctl block and rewrite core words only
    new = arena.pack(lay, q, ctx, meta, tele=arena.tele_of(lay, ctl))
    return new.mem, new.ctl


def migrate_math(cfg: HeapConfig, kind: str, family: str, mem, ctl,
                 src, dst, sizes):
    """One whole migration wave on one arena (extract → insert →
    class-major rebuild): the jnp oracle AND the whole-lowering kernel
    body.  Returns ``(mem', ctl')``."""
    if kind != "chunk":
        return mem, ctl
    M = src.shape[0]
    buf = jnp.zeros((M, cfg.words_per_chunk), jnp.int32)
    valid = (src >= 0) & (dst >= 0)
    mem, buf = extract_math(cfg, kind, family, mem, ctl, src, sizes,
                            valid, buf)
    return insert_rebuild_math(cfg, kind, family, mem, ctl, dst, sizes,
                               valid, buf)


def sharded_migrate_math(cfg: HeapConfig, num_shards: int, kind: str,
                         family: str, mem, ctl, src, dst, sizes):
    """Sharded wave: extract over every shard, then insert+rebuild over
    every shard (phase-major, shard-minor — the serial replay both
    Pallas lowerings grid).  Cross-shard moves ride the carry buffer
    between the phases; every shard is rebuilt, so donors retire their
    emptied chunks in the same wave.  Returns ``(mem', ctl')``."""
    from repro.core import shards  # lazy: defrag <-> shards
    if kind != "chunk":
        return mem, ctl
    scfg = shards.shard_config(cfg, num_shards)
    Ws = scfg.total_words
    M = src.shape[0]
    buf = jnp.zeros((M, scfg.words_per_chunk), jnp.int32)
    src_sh = jnp.where(src >= 0, src // Ws, -1)
    dst_sh = jnp.where(dst >= 0, dst // Ws, -1)
    valid = (src >= 0) & (dst >= 0)

    def ext_step(carry, s):
        mem, buf = carry
        sel = valid & (src_sh == s)
        local = jnp.where(sel, src - s * Ws, -1)
        m2, buf = extract_math(
            scfg, kind, family,
            jax.lax.dynamic_index_in_dim(mem, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ctl, s, 0, keepdims=False),
            local, sizes, sel, buf)
        return (jax.lax.dynamic_update_index_in_dim(mem, m2, s, 0),
                buf), None

    def ins_step(carry, s):
        mem, ctl, buf = carry
        sel = valid & (dst_sh == s)
        local = jnp.where(sel, dst - s * Ws, -1)
        m2, c2 = insert_rebuild_math(
            scfg, kind, family,
            jax.lax.dynamic_index_in_dim(mem, s, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(ctl, s, 0, keepdims=False),
            local, sizes, sel, buf)
        mem = jax.lax.dynamic_update_index_in_dim(mem, m2, s, 0)
        ctl = jax.lax.dynamic_update_index_in_dim(ctl, c2, s, 0)
        return (mem, ctl, buf), None

    srange = jnp.arange(num_shards, dtype=jnp.int32)
    (mem, buf), _ = jax.lax.scan(ext_step, (mem, buf), srange)
    (mem, ctl, _), _ = jax.lax.scan(ins_step, (mem, ctl, buf), srange)
    return mem, ctl


# --------------------------------------------------------------------------
# fragmentation observability
# --------------------------------------------------------------------------

def _pool_members(cfg: HeapConfig, pool):
    """Bool mask over chunk ids: currently queued in the free pool."""
    nc = cfg.num_chunks
    cnt = (pool.back - pool.front)[0]
    k = jnp.arange(nc, dtype=jnp.int32)
    slots = (pool.front[0] + k) % nc
    ids = pool.store[0, slots]
    live = k < cnt
    return jnp.zeros(nc, bool).at[
        jnp.where(live & (ids >= 0) & (ids < nc), ids, nc)].set(
        True, mode="drop")


def frag_stats_math(cfg: HeapConfig, kind: str, family: str, mem, ctl):
    """``(free_words, largest_free_extent)`` of one arena.

    Chunk kinds: word-exact — a word is free iff its chunk sits in the
    pool (fully reusable) or it belongs to a free page of a bound
    chunk; the largest extent is the longest contiguous free run.
    Page kinds carve inventory at init, so free words are the queued
    per-class inventories and the largest extent is the largest page
    still grantable (the allocator can never grant more contiguously).
    """
    lay = arena.layout(cfg, kind, family)
    C = cfg.num_classes
    if kind != "chunk":
        front = ctl[lay.off_front:lay.off_front + C]
        back = ctl[lay.off_back:lay.off_back + C]
        counts = back - front
        pws = jnp.array([cfg.page_words(c) for c in range(C)], jnp.int32)
        free_words = jnp.sum(counts * pws)
        largest = jnp.max(jnp.where(counts > 0, pws, 0))
        return free_words, largest
    _, ctx, meta = arena.unpack(lay, arena.Arena(mem, ctl))
    nc = cfg.num_chunks
    wpc = cfg.words_per_chunk
    maxbits = cfg.bitmap_words_per_chunk * 32
    occ = _occupancy_bits(meta.bitmap)
    bound = meta.chunk_class >= 0
    cc = jnp.clip(meta.chunk_class, 0, C - 1)
    pw = jnp.left_shift(cfg.page_words(0), cc).astype(jnp.int32)
    ppc = jnp.right_shift(cfg.max_pages_per_chunk, cc)
    free_page = (~occ) & bound[:, None] \
        & (jnp.arange(maxbits, dtype=jnp.int32)[None, :] < ppc[:, None])
    word_page = jnp.minimum(
        jnp.arange(wpc, dtype=jnp.int32)[None, :] // pw[:, None],
        maxbits - 1)
    in_pool = _pool_members(cfg, ctx.pool)
    free_mask = (in_pool[:, None]
                 | (bound[:, None] & jnp.take_along_axis(
                     free_page, word_page, axis=1))).reshape(-1)
    idx = jnp.arange(free_mask.shape[0], dtype=jnp.int32)
    last_blocked = jax.lax.cummax(jnp.where(~free_mask, idx, -1))
    run = jnp.where(free_mask, idx - last_blocked, 0)
    return jnp.sum(free_mask.astype(jnp.int32)), jnp.max(run)


def frag_ratio(free_words, largest_free_extent):
    """``1 − largest_free/total_free`` ∈ [0, 1): 0 = one solid free
    block, → 1 = free space shattered into small extents."""
    total = jnp.maximum(free_words, 1)
    r = 1.0 - largest_free_extent.astype(jnp.float32) / total
    return jnp.where(free_words > 0, r, 0.0)
