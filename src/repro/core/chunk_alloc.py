"""Chunk allocator (paper §4.2, fig. 2) over any queue family.

"The chunk allocator maintains queues of chunks that have free pages,
first obtaining a chunk index, then scanning the chunk for free pages.
It is a more complex algorithm, but queue sizes are smaller."

Queues hold *chunk ids*; every chunk carries a page-occupancy bitmap.
Allocation pops a chunk from the class queue (or claims a fresh chunk
from the pool), rank-selects free bits from its bitmap, and re-enqueues
the chunk if pages remain.  Freeing clears bits and re-enqueues chunks
on their full→non-full transition.

Deviation from GPU Ouroboros (documented in DESIGN.md §6): a chunk stays
bound to its size class once claimed; GPU Ouroboros can reflag an
emptied chunk back to the global pool mid-queue, which requires the
lock-free flag dance we have no atomics for.  `compact()` on the host
rebuilds the binding (used by the serving engine between batches).

Like page_alloc, this module is now the chunk-kind transaction *math*
under the core/transactions.py dispatcher: state arrives as views of
the flat arena (bitmaps/free counts/bindings at fixed word offsets of
``mem``), and the same body runs as the jnp oracle and inside the
fused single-kernel Pallas transaction.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import groups, queues
from repro.core.heap import HeapConfig, size_to_class_device
from repro.core.page_alloc import AllocState


class ChunkMeta(NamedTuple):
    bitmap: Any       # (num_chunks, bitmap_words) uint32, 1 = page in use
    free_count: Any   # (num_chunks,) int32
    chunk_class: Any  # (num_chunks,) int32, -1 = unbound


def init(cfg: HeapConfig, family_name: str) -> AllocState:
    C = cfg.num_classes
    ctx = queues.AllocCtx(heap=jnp.zeros(cfg.total_words, jnp.int32),
                          pool=queues.pool_init(cfg))
    if family_name == "ring":
        q = queues.ring_init(C, cfg.num_chunks)
    else:
        q, ctx = queues.virt_init(cfg, ctx, C, cfg.num_chunks, family_name)
    meta = ChunkMeta(
        bitmap=jnp.zeros((cfg.num_chunks, cfg.bitmap_words_per_chunk),
                         jnp.uint32),
        free_count=jnp.zeros(cfg.num_chunks, jnp.int32),
        chunk_class=jnp.full(cfg.num_chunks, -1, jnp.int32),
    )
    return AllocState(q=q, ctx=ctx, meta=meta)


def _expand_bitmap(row, nbits):
    """(bitmap_words,) uint32 → (nbits,) bool of per-page occupancy."""
    bits = (row[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & 1
    return bits.reshape(-1)[:nbits].astype(bool)


def _select_free_pages(row, ppc, take):
    """Rank-select: indices of the first ``take`` free pages of a chunk.

    The pure-jnp form of the ``bitmap_select`` Pallas kernel (kernels/
    bitmap_select.py is the tiled version for big bitmaps).
    Returns (page_idx (maxppc,), valid (maxppc,)) padded arrays.
    """
    occupied = _expand_bitmap(row, row.shape[0] * 32)
    in_range = jnp.arange(occupied.shape[0]) < ppc
    free = (~occupied) & in_range
    order = jnp.cumsum(free.astype(jnp.int32)) - free.astype(jnp.int32)
    chosen = free & (order < take)
    page_idx = jnp.nonzero(chosen, size=occupied.shape[0], fill_value=-1)[0]
    valid = page_idx >= 0
    return page_idx.astype(jnp.int32), valid


def _set_bits(meta: ChunkMeta, chunk, page_idx, valid, delta_sign):
    """Set (+1) or clear (−1) unique page bits via scatter-add.

    Bits are unique per (chunk, page) and in the opposite state, so
    add/subtract of the bit value equals OR/AND-NOT (double-free is UB,
    as in the C original)."""
    word = page_idx // 32
    bitval = (jnp.uint32(1) << (page_idx % 32).astype(jnp.uint32))
    signed = jnp.where(delta_sign > 0, bitval, jnp.uint32(0) - bitval)
    ch = jnp.where(valid, chunk, meta.bitmap.shape[0])
    bitmap = meta.bitmap.at[ch, word].add(jnp.where(valid, signed, 0),
                                          mode="drop")
    nfree = meta.free_count.at[ch].add(
        jnp.where(valid, -delta_sign, 0), mode="drop")
    return meta._replace(bitmap=bitmap, free_count=nfree)


def alloc(cfg: HeapConfig, family_name: str, state: AllocState,
          sizes_bytes, mask, backend: str = "jnp"):
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    n = sizes_bytes.shape[0]
    maxppc = cfg.max_pages_per_chunk
    cls = size_to_class_device(cfg, sizes_bytes)
    valid = mask & (cls < C)
    counts = groups.segment_counts(cls, valid, C)
    out = jnp.full(n, -1, jnp.int32)

    q, ctx, meta = state.q, state.ctx, state.meta
    one = jnp.ones(1, bool)

    for c in range(C):  # static class loop; dynamic chunk-drain inside
        ppc = cfg.pages_per_chunk(c)
        pw = cfg.page_words(c)
        req_pos = jnp.nonzero(valid & (cls == c), size=n, fill_value=n)[0]

        def body(carry):
            q, ctx, meta, out, served, fail = carry
            have_queued = fam.count(q)[c] > 0

            def from_queue(op):
                q, ctx, meta = op
                rank = jnp.zeros(1, jnp.int32)
                ccls = jnp.full(1, c, jnp.int32)
                q, ctx, ch = fam.bulk_dequeue(cfg, q, ctx, ccls, rank, one,
                                              backend)
                return q, ctx, meta, ch[0], jnp.array(False)

            def from_pool(op):
                q, ctx, meta = op
                has = queues.pool_count(ctx.pool) > 0
                pool, ch = queues.pool_dequeue(cfg, ctx.pool, one & has,
                                               backend)
                ch = ch[0]
                sent = meta.bitmap.shape[0]
                idx = jnp.where(has, ch, sent)
                bitmap = meta.bitmap.at[idx].set(jnp.uint32(0), mode="drop")
                nfree = meta.free_count.at[idx].set(ppc, mode="drop")
                ccls = meta.chunk_class.at[idx].set(c, mode="drop")
                meta = ChunkMeta(bitmap, nfree, ccls)
                return q, ctx._replace(pool=pool), meta, ch, ~has

            q, ctx, meta, chunk, fail_now = jax.lax.cond(
                have_queued, from_queue, from_pool, (q, ctx, meta))

            f = jnp.where(fail_now, 0, meta.free_count[chunk])
            t = jnp.minimum(counts[c] - served, f)
            if backend == "pallas":
                # fused rank-select + bit claim + free-count delta in
                # one kernel (kernels/alloc_txn.chunk_txn_claim)
                from repro.kernels import ops as kops
                page_idx, new_row, nsel = kops.chunk_txn_claim(
                    meta.bitmap[chunk], t, ppc=ppc)
                sel = page_idx >= 0
                gate = jnp.where(nsel[0] > 0, chunk, meta.bitmap.shape[0])
                meta = meta._replace(
                    bitmap=meta.bitmap.at[gate].set(new_row, mode="drop"),
                    free_count=meta.free_count.at[gate].add(
                        -nsel[0], mode="drop"))
            else:
                page_idx, sel = _select_free_pages(meta.bitmap[chunk],
                                                   ppc, t)
                meta = _set_bits(meta, chunk, page_idx, sel, +1)
            offs = chunk * cfg.words_per_chunk + page_idx * pw
            dst = req_pos.at[served + jnp.arange(page_idx.shape[0])].get(
                mode="fill", fill_value=n)
            out = out.at[jnp.where(sel, dst, n)].set(offs, mode="drop")

            # chunk still has pages → back into the class queue
            leftover = (~fail_now) & (meta.free_count[chunk] > 0)
            ccls = jnp.full(1, c, jnp.int32)
            q, ctx = fam.bulk_enqueue(
                cfg, q, ctx, ccls, jnp.zeros(1, jnp.int32),
                jnp.full(1, chunk, jnp.int32), one & leftover, backend)
            return q, ctx, meta, out, served + t, fail | fail_now

        def cond(carry):
            *_, served, fail = carry
            return (served < counts[c]) & ~fail

        q, ctx, meta, out, _, _ = jax.lax.while_loop(
            cond, body, (q, ctx, meta, out, jnp.int32(0), jnp.array(False)))

    return AllocState(q=q, ctx=ctx, meta=meta), out


def free(cfg: HeapConfig, family_name: str, state: AllocState,
         offsets_words, sizes_bytes, mask, backend: str = "jnp"):
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    n = offsets_words.shape[0]
    cls = size_to_class_device(cfg, sizes_bytes)
    valid = mask & (cls < C) & (offsets_words >= 0)

    meta = state.meta
    chunk = offsets_words // cfg.words_per_chunk
    # page_words(c) = min_page_words << c, computed as a shift so no
    # table constant is captured inside the fused arena kernel.
    pw = jnp.left_shift(cfg.page_words(0), cls % C).astype(jnp.int32)
    page_idx = (offsets_words % cfg.words_per_chunk) // pw

    old_free = meta.free_count  # snapshot before clearing
    meta = _set_bits(meta, chunk, page_idx, valid, -1)

    # full → non-full transitions re-enter the class queue.
    touched = jnp.zeros(cfg.num_chunks, bool).at[
        jnp.where(valid, chunk, cfg.num_chunks)].set(True, mode="drop")
    revived = touched & (old_free == 0)
    rev_ids = jnp.nonzero(revived, size=n, fill_value=-1)[0].astype(jnp.int32)
    rev_ok = rev_ids >= 0
    rev_cls = meta.chunk_class.at[rev_ids].get(mode="fill", fill_value=0)
    rank, _ = groups.masked_rank(rev_cls, rev_ok, C)
    q, ctx = fam.bulk_enqueue(cfg, state.q, state.ctx, rev_cls, rank,
                              rev_ids, rev_ok, backend)
    return AllocState(q=q, ctx=ctx, meta=meta)


def compact(cfg: HeapConfig, family_name: str, state: AllocState
            ) -> AllocState:
    """Defragmentation: rebuild queues so fully-free chunks return to
    the pool (GPU Ouroboros does this online with flag CAS; see module
    docstring).  jit-safe; the serving engine runs it between batches."""
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    meta = state.meta
    nc = cfg.num_chunks
    ids = jnp.arange(nc, dtype=jnp.int32)

    ppc_table = jnp.array([0] + [cfg.pages_per_chunk(c) for c in range(C)],
                          jnp.int32)
    fully_free = (meta.chunk_class >= 0) & (
        meta.free_count == ppc_table.at[meta.chunk_class + 1].get(mode="clip"))
    chunk_class = jnp.where(fully_free, -1, meta.chunk_class)
    meta = meta._replace(chunk_class=chunk_class)

    # Fresh pool primed with every unbound chunk, then fresh queues with
    # every live (bound, has-free-pages) chunk re-enqueued.
    unbound = chunk_class < 0
    rank = groups.masked_prefix_sum(jnp.ones(nc, jnp.int32), unbound)
    pool, _ = queues.ring_bulk_enqueue(
        cfg, queues.ring_init(1, nc), None, jnp.zeros(nc, jnp.int32),
        rank, ids, unbound)
    ctx = queues.AllocCtx(heap=state.ctx.heap, pool=pool)

    if family_name == "ring":
        q = queues.ring_init(C, nc)
    else:
        q, ctx = queues.virt_init(cfg, ctx, C, nc, family_name)
    live = (chunk_class >= 0) & (meta.free_count > 0)
    rk, _ = groups.masked_rank(chunk_class, live, C)
    q, ctx = fam.bulk_enqueue(cfg, q, ctx, chunk_class, rk, ids, live)
    return AllocState(q=q, ctx=ctx, meta=meta)
