"""Page allocator (paper §4.1, fig. 1) over any queue family.

"The simplest allocator is the page-based allocator, where pages of
fixed size are allocated from a queue. Total heap memory is divided
amongst the queues, each queue managing a different page size."

Init carves the data chunks evenly into per-class page inventories and
enqueues every page offset.  ``alloc`` is a single bulk dequeue (after
the lane-aggregated ranking), ``free`` a single bulk enqueue — the
fastest variant, but fragmentation is fixed at init, exactly as the
paper observes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core import groups, queues
from repro.core.heap import HeapConfig, size_to_class_device

# Since the arena refactor this module is the page-kind *transaction
# math*: core/transactions.py unpacks the flat arena (core/arena.py)
# into the view pytrees below, runs these functions with the backend
# pinned to "jnp", and repacks — and the Pallas backend executes the
# very same body inside one fused kernel (kernels/alloc_txn.
# arena_*_txn), segment walk included.  The local ``backend="pallas"``
# branches below survive for the piecewise PR-1 kernels, which
# tests/test_kernels.py still validates in isolation; bit-exact parity
# of the full transactions is enforced by tests/test_alloc_txn_parity.py.


class AllocState(NamedTuple):
    q: Any                 # queue-family state
    ctx: queues.AllocCtx   # heap words + free-chunk pool
    meta: Any              # ChunkMeta for chunk allocators, None here


def data_chunks_per_class(cfg: HeapConfig) -> int:
    """Even split with one class-share held back for virtualized queue
    segments (moved to HeapConfig so core/arena.py sizes the queue
    region from the same bound)."""
    return cfg.data_chunks_per_class


def init(cfg: HeapConfig, family_name: str) -> AllocState:
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    share = data_chunks_per_class(cfg)
    ctx = queues.AllocCtx(heap=jnp.zeros(cfg.total_words, jnp.int32),
                          pool=queues.pool_init(cfg))

    max_items = share * cfg.pages_per_chunk(0)
    if family_name == "ring":
        q = queues.ring_init(C, max_items)
    else:
        q, ctx = queues.virt_init(cfg, ctx, C, max_items, family_name)

    # Claim each class's chunk share from the pool and enqueue its pages.
    for c in range(C):
        mask = jnp.ones(share, bool)
        pool, chunk_ids = queues.pool_dequeue(cfg, ctx.pool, mask)
        ctx = ctx._replace(pool=pool)
        ppc = cfg.pages_per_chunk(c)
        pw = cfg.page_words(c)
        offs = (chunk_ids[:, None] * cfg.words_per_chunk
                + jnp.arange(ppc, dtype=jnp.int32)[None, :] * pw).reshape(-1)
        cls = jnp.full(offs.shape[0], c, jnp.int32)
        rank = jnp.arange(offs.shape[0], dtype=jnp.int32)
        q, ctx = fam.bulk_enqueue(cfg, q, ctx, cls, rank, offs,
                                  jnp.ones_like(offs, bool))
    return AllocState(q=q, ctx=ctx, meta=None)


def alloc(cfg: HeapConfig, family_name: str, state: AllocState,
          sizes_bytes, mask, backend: str = "jnp"):
    """Bulk allocation.  Returns (state, word_offsets) — offset −1 marks
    a failed request (over-large size or exhausted inventory), matching
    the GPU original's nullptr."""
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    cls = size_to_class_device(cfg, sizes_bytes)
    valid = mask & (cls < C)
    if backend == "pallas" and family_name == "ring":
        # one fused kernel: in-kernel masked rank, inventory grant,
        # wrapped window pop, and front advance (kernels/alloc_txn.py).
        from repro.kernels import ops as kops
        offs, new_front = kops.ring_txn_pop(
            state.q.store, state.q.front, state.q.back, cls, valid,
            limit=True)
        q = state.q._replace(front=new_front)
        return AllocState(q=q, ctx=state.ctx, meta=None), offs
    rank, _ = groups.masked_rank(cls, valid, C)
    avail = fam.count(state.q)
    # Grants are the per-class rank prefix that fits current inventory;
    # denied lanes are exactly the tail ranks so ranks stay dense.
    grant = valid & (rank < avail[cls % C])
    q, ctx, offs = fam.bulk_dequeue(cfg, state.q, state.ctx, cls, rank,
                                    grant, backend)
    return AllocState(q=q, ctx=ctx, meta=None), offs


def free(cfg: HeapConfig, family_name: str, state: AllocState,
         offsets_words, sizes_bytes, mask, backend: str = "jnp"):
    fam = queues.FAMILIES[family_name]
    C = cfg.num_classes
    cls = size_to_class_device(cfg, sizes_bytes)
    valid = mask & (cls < C) & (offsets_words >= 0)
    if backend == "pallas" and family_name == "ring":
        from repro.kernels import ops as kops
        store, new_back = kops.ring_txn_push(
            state.q.store, state.q.back, cls, offsets_words, valid)
        q = state.q._replace(store=store, back=new_back)
        return AllocState(q=q, ctx=state.ctx, meta=None)
    rank, _ = groups.masked_rank(cls, valid, C)
    q, ctx = fam.bulk_enqueue(cfg, state.q, state.ctx, cls, rank,
                              offsets_words, valid, backend)
    return AllocState(q=q, ctx=ctx, meta=None)
