"""Paged KV cache backed by the Ouroboros allocator.

The serving-side embodiment of the paper's technique: KV pages are
dynamically allocated per sequence from an Ouroboros heap (default
variant ``vl_chunk`` — the virtualized-list chunk allocator, which
claims chunks on demand with no init-time carve) and addressed through
a page table, vLLM-style but with the allocator running *on device* as
bulk transactions.

Layout: page heaps are stacked over attention layers — one page id
backs all layers' K/V slots for its 16-token span (page tables are
layer-invariant, as in vLLM).  Optional int8 quantization stores a per
(slot, head) scale — this is what makes qwen1.5-32b's decode_32k cell
fit v5e HBM (DESIGN.md §Arch-applicability).

Single-layer cores (``append1`` / ``prefill_write1`` / ``paged_attend1``)
are what the model's scan-over-layers consumes; the ``PagedKV``
container stacks them for the serving engine.  ``paged_attend1`` is the
GSPMD-shardable jnp decode attention (blockwise online softmax over
page-table gathers); kernels/paged_attention.py is the single-chip TPU
Pallas fast path validated against the same oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros

PAGE_SIZE = 16  # tokens per KV page
_NEG = -1e30

# Analysis override: the dry-run sets this to the full table width so
# the page-block loop disappears and HLO cost analysis sees every flop
# (a while body is only counted once).  Execution memory profiles use
# the normal blocked path (override None).
_PB_OVERRIDE = None

# Dense-prefill fast path: when the page table is the canonical layout
# (page id = b·P + j, the engine's bulk-reservation order) a prefill KV
# write is a pure reshape — no scatter.  GSPMD cannot partition the
# general scatter into a fully-sharded heap and replicates it (observed
# ~46 GiB/chip extra on qwen1.5-32b×prefill_32k).  Enabled by the
# dry-run/serving launcher; the engine's arbitrary-id path keeps the
# scatter.
_DENSE_PREFILL = False


def set_page_block_override(v):
    global _PB_OVERRIDE
    _PB_OVERRIDE = v


def set_dense_prefill(v: bool):
    global _DENSE_PREFILL
    _DENSE_PREFILL = bool(v)


class KVLayer(NamedTuple):
    """One attention layer's page heap (the scan-over-layers unit)."""
    k: jnp.ndarray                  # (NP, page, Hkv, hd) kv_dtype
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]  # (NP, page, Hkv) f32 — int8 KV only
    v_scale: Optional[jnp.ndarray]


class PagedKV(NamedTuple):
    layers: KVLayer                 # arrays stacked: (L, NP, page, Hkv, hd)
    page_table: jnp.ndarray         # (B, P) int32, -1 = hole
    seq_lens: jnp.ndarray           # (B,) int32 — tokens already cached

    @property
    def page(self) -> int:
        return self.layers.k.shape[2]


def init_paged_kv(num_layers: int, num_pages: int, batch: int,
                  max_pages_per_seq: int, num_kv_heads: int, head_dim: int,
                  kv_dtype=jnp.bfloat16, page: int = PAGE_SIZE) -> PagedKV:
    shape = (num_layers, num_pages, page, num_kv_heads, head_dim)
    quant = kv_dtype == jnp.int8
    return PagedKV(
        layers=KVLayer(
            k=jnp.zeros(shape, kv_dtype),
            v=jnp.zeros(shape, kv_dtype),
            k_scale=jnp.zeros(shape[:4], jnp.float32) if quant else None,
            v_scale=jnp.zeros(shape[:4], jnp.float32) if quant else None),
        page_table=jnp.full((batch, max_pages_per_seq), -1, jnp.int32),
        seq_lens=jnp.zeros(batch, jnp.int32),
    )


def abstract_paged_kv(num_layers, num_pages, batch, max_pages_per_seq,
                      num_kv_heads, head_dim, kv_dtype=jnp.bfloat16,
                      page: int = PAGE_SIZE) -> PagedKV:
    """ShapeDtypeStruct twin of ``init_paged_kv`` for the dry-run."""
    shape = (num_layers, num_pages, page, num_kv_heads, head_dim)
    quant = kv_dtype == jnp.int8
    sds = jax.ShapeDtypeStruct
    return PagedKV(
        layers=KVLayer(
            k=sds(shape, kv_dtype), v=sds(shape, kv_dtype),
            k_scale=sds(shape[:4], jnp.float32) if quant else None,
            v_scale=sds(shape[:4], jnp.float32) if quant else None),
        page_table=sds((batch, max_pages_per_seq), jnp.int32),
        seq_lens=sds((batch,), jnp.int32),
    )


def make_kv_allocator(num_pages: int, backend: str = "jnp",
                      lowering: str = "auto", num_shards: int = 1):
    """Ouroboros instance managing the page-id space.

    Each logical page is one 256 B region of a single-size-class heap;
    ``vl_chunk`` claims chunks lazily so the full page space is usable.
    offset//64 (words) ↔ page id.  Allocator state is the flat
    device-resident arena (core/arena.py) — the vl chunk queues, their
    next-pointer chains, bitmaps, and counters all live at fixed word
    offsets in it, so with ``backend="pallas"`` every page grant and
    release the engine issues is ONE fused kernel launch, segment walk
    included; ``lowering`` picks the kernel shape (whole-arena refs vs
    the region-blocked compiled lowering, DESIGN.md §8).  Backends and
    lowerings are bit-identical, so serving behaviour is invariant to
    both — which is also why the serving snapshot fingerprint
    (DESIGN.md §12) records this allocator's layout/geometry but NOT
    its backend/lowering: a snapshot taken on one restores onto the
    other mid-stream.

    ``num_shards > 1`` partitions the page space into that many
    independent arenas (core/shards.py, DESIGN.md §9): the heap is
    sized so EACH shard carries the per-shard page share plus its own
    vl segment overhead, the engine routes each sequence's grants to
    ``slot % num_shards`` via ``shard_hint``, and exhausted shards
    overflow to neighbors — page ids stay global either way.

    Returns (ouro, words_per_page, physical_pages).  Queue segments live
    in the same heap (the ouroboros property), so granted ids are a
    subset of [0, physical_pages) that skips segment-occupied chunks —
    size the KV page array with ``physical_pages``, never ``num_pages``
    (ids beyond the array would silently drop KV writes).

    >>> import jax.numpy as jnp
    >>> from repro.paged.kv_cache import make_kv_allocator
    >>> ouro, wpp, physical = make_kv_allocator(64)
    >>> state = ouro.init()
    >>> sizes = jnp.full(4, 256, jnp.int32)     # four page grants
    >>> state, offs = ouro.alloc(state, sizes, jnp.ones(4, bool))
    >>> page_ids = [int(o) // wpp for o in offs]
    >>> all(0 <= p < physical for p in page_ids)
    True
    """
    chunk = 4096
    pages_per_chunk = chunk // 256
    pages_per_shard = -(-num_pages // num_shards)
    data_chunks = -(-pages_per_shard // pages_per_chunk)
    # vl segments: one per size class (5) + chunk-queue chain growth
    # (1023 ids per segment) + headroom — per shard.
    seg_chunks = 5 + data_chunks // 1023 + 3
    cfg = HeapConfig(
        total_bytes=num_shards * (data_chunks + seg_chunks) * chunk,
        chunk_bytes=chunk, min_page_bytes=256)
    physical_pages = cfg.total_words // 64
    return (Ouroboros(cfg, "vl_chunk", backend, lowering,
                      num_shards=num_shards), 64, physical_pages)


def modality_page_quota(cfg, page_bytes: int = 256) -> int:
    """Arena pages of per-sequence state residency BEYOND the KV pages
    — the per-modality allocation policy (DESIGN.md §13).

    The paper's claim is ONE dynamic allocator for heterogeneous
    workloads, so every model family's per-sequence state rides the
    same Ouroboros arena the KV pages come from.  Attention KV grows
    page-by-page with the sequence (``make_kv_allocator``); what this
    helper sizes is the O(1)-per-sequence state the other families
    carry instead of (or on top of) KV:

    - ``ssm`` (mamba2): the SSD recurrent state — ``(nheads, headdim,
      state)`` f32 plus the ``(conv-1, conv_dim)`` bf16 convolution
      tail, per layer;
    - ``hybrid`` (recurrentgemma): the RG-LRU recurrence — ``(lru_width,)``
      f32 hidden plus the ``(3, lru_width)`` bf16 conv tail, per
      recurrent (non-attention) layer;
    - ``moe`` (mixtral, phi3.5): the routed expert activation buffers —
      ``top_k × d_ff`` bf16 per MoE layer;
    - dense / enc-dec / vlm: 0 (their per-sequence state is entirely
      KV pages).

    The serving engine grants this many pages per slot at admission
    (``slot_aux``) and frees them at retirement/eviction/cancel, so
    SSM and MoE traffic exercises the allocator even though their
    state tensors live in dense device arrays.

    >>> from repro.configs import get_arch
    >>> from repro.paged.kv_cache import modality_page_quota
    >>> modality_page_quota(get_arch("qwen2-0.5b").smoke())
    0
    >>> modality_page_quota(get_arch("mamba2-780m").smoke()) > 0
    True
    """
    if cfg.family == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        per_layer = (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
                     + (cfg.ssm_conv - 1) * conv_dim * 2)
        return -(-cfg.num_layers * per_layer // page_bytes)
    if cfg.family == "hybrid":
        r = cfg.lru_width or cfg.d_model
        n_rec = cfg.num_layers - cfg.num_layers // cfg.attn_period
        return -(-n_rec * (r * 4 + 3 * r * 2) // page_bytes)
    if cfg.num_experts:
        buf = cfg.num_layers * cfg.num_experts_per_tok * cfg.d_ff * 2
        return -(-buf // page_bytes)
    return 0


def scatter_grant_words(page_table, page_counts, lane_slot, lane_rank,
                        lane_offs, grant_ok, wpp: int):
    """Scatter freshly granted arena WORD offsets into the device page
    table — the mega-step path where the table is never materialized on
    the host: grants flow kernel → page id (``offset // wpp``) → table
    entirely on device.  Lane ``j`` lands at row ``lane_slot[j]``,
    column ``page_counts[slot] + lane_rank[j]`` (the slot's next free
    table slots, in grant order); lanes with ``grant_ok[j]`` False are
    dropped.

    >>> import jax.numpy as jnp
    >>> from repro.paged.kv_cache import scatter_grant_words
    >>> pt = jnp.full((2, 3), -1, jnp.int32)
    >>> pt = scatter_grant_words(
    ...     pt, jnp.array([1, 0]),                  # pages already mapped
    ...     jnp.array([0, 1]), jnp.array([0, 0]),   # lane slot / rank
    ...     jnp.array([128, 0]),                    # granted word offsets
    ...     jnp.array([True, True]), wpp=64)
    >>> pt.tolist()
    [[-1, 2, -1], [0, -1, -1]]
    """
    B, P = page_table.shape
    pages = (lane_offs // wpp).astype(jnp.int32)
    row = jnp.where(grant_ok, lane_slot, B)
    col = jnp.where(grant_ok, page_counts[lane_slot] + lane_rank, P)
    return page_table.at[row, col].set(pages, mode="drop")


def forwarding_page_map(fwd, wpp: int, max_span: int):
    """Expand a defrag :class:`~repro.core.defrag.Forwarding` table to
    page granularity: ``(src_pids, dst_pids)`` int32 arrays (−1 padded),
    one entry per migrated page (a multi-page extent contributes one
    entry per page).  ``max_span`` bounds pages per extent — the
    allocator's ``words_per_chunk // wpp``."""
    k = fwd.sizes // (wpp * 4)
    j = jnp.arange(max_span, dtype=jnp.int32)[None, :]
    ok = (fwd.src >= 0)[:, None] & (j < k[:, None])
    sp = jnp.where(ok, fwd.src[:, None] // wpp + j, -1)
    dp = jnp.where(ok, fwd.dst[:, None] // wpp + j, -1)
    return sp.reshape(-1), dp.reshape(-1)


def apply_forwarding(kv: PagedKV, fwd, wpp: int,
                     max_span: Optional[int] = None) -> PagedKV:
    """Apply a defrag forwarding table to the paged cache: move the
    migrated pages' K/V rows (and scales) to their new physical page
    ids and rewrite every matching page-table entry — after which
    reads through the table are word-identical to pre-defrag reads
    (tests/test_defrag.py pins this).

    ``max_span`` bounds pages per forwarded extent; by default it is
    derived from the concrete table (``None`` under tracing raises —
    pass the allocator's ``words_per_chunk // wpp`` there, as the
    engine does).

    >>> import jax.numpy as jnp
    >>> from repro.core.defrag import Forwarding
    >>> from repro.paged.kv_cache import apply_forwarding, init_paged_kv
    >>> kv = init_paged_kv(1, num_pages=4, batch=1, max_pages_per_seq=2,
    ...                    num_kv_heads=1, head_dim=2,
    ...                    kv_dtype=jnp.float32)
    >>> kv = kv._replace(
    ...     layers=kv.layers._replace(k=kv.layers.k.at[:, 3].set(7.0)),
    ...     page_table=kv.page_table.at[0, 0].set(3))
    >>> fwd = Forwarding(src=jnp.array([3 * 64], jnp.int32),
    ...                  dst=jnp.array([0], jnp.int32),
    ...                  sizes=jnp.array([256], jnp.int32))
    >>> kv2 = apply_forwarding(kv, fwd, wpp=64)
    >>> int(kv2.page_table[0, 0]), float(kv2.layers.k[0, 0, 0, 0, 0])
    (0, 7.0)
    """
    if max_span is None:
        try:
            max_span = max(1, int(jnp.max(fwd.sizes // (wpp * 4))))
        except jax.errors.ConcretizationTypeError as e:
            raise ValueError(
                "apply_forwarding needs an explicit max_span under jit "
                "tracing (the allocator's words_per_chunk // wpp)"
            ) from e
    sp, dp = forwarding_page_map(fwd, wpp, max_span)
    np_ = kv.layers.k.shape[1]
    moved = sp >= 0
    safe_sp = jnp.where(moved, sp, 0)
    safe_dp = jnp.where(moved, dp, np_)

    def relocate(heap):
        if heap is None:
            return None
        # unmoved lanes target row np_ (one past the end) and drop
        return heap.at[:, safe_dp].set(heap[:, safe_sp], mode="drop")

    layers = KVLayer(*(relocate(x) for x in kv.layers))
    key = jnp.where(moved, sp, jnp.int32(-2))
    hit = kv.page_table[:, :, None] == key[None, None, :]
    new = jnp.sum(jnp.where(hit, dp[None, None, :], 0), axis=-1)
    table = jnp.where(hit.any(-1), new, kv.page_table)
    return kv._replace(layers=layers, page_table=table)


def _quant(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-8
    return jnp.round(x / scale).astype(jnp.int8), scale[..., 0]


def _store(layer: KVLayer, idx, k_new, v_new) -> KVLayer:
    if layer.k_scale is not None:
        kq, ks = _quant(k_new.astype(jnp.float32))
        vq, vs = _quant(v_new.astype(jnp.float32))
        return KVLayer(
            k=layer.k.at[idx].set(kq, mode="drop"),
            v=layer.v.at[idx].set(vq, mode="drop"),
            k_scale=layer.k_scale.at[idx].set(ks, mode="drop"),
            v_scale=layer.v_scale.at[idx].set(vs, mode="drop"))
    return layer._replace(
        k=layer.k.at[idx].set(k_new.astype(layer.k.dtype), mode="drop"),
        v=layer.v.at[idx].set(v_new.astype(layer.v.dtype), mode="drop"))


def append1(layer: KVLayer, page_table, seq_lens, k_t, v_t,
            ring: bool = False) -> KVLayer:
    """Write one new token's K/V at position ``seq_lens`` per sequence.
    k_t, v_t: (B, 1, Hkv, hd).  Pages must already be mapped.
    ``ring``: windowed attention — table slot = page_index mod P, so a
    window-sized table serves unbounded sequences (page reuse)."""
    page = layer.k.shape[1]
    np_ = layer.k.shape[0]
    P = page_table.shape[1]
    pidx, slot = seq_lens // page, seq_lens % page
    if ring:
        pidx = pidx % P
    ids = jnp.take_along_axis(page_table, pidx[:, None], axis=1)[:, 0]
    idx = (jnp.where(ids >= 0, ids, np_), slot)
    return _store(layer, idx, k_t[:, 0], v_t[:, 0])


def prefill_write1(layer: KVLayer, page_table, k, v, pos0=0,
                   ring: bool = False) -> KVLayer:
    """Bulk-write a prefill segment (S tokens).  k, v: (B, S, Hkv, hd)."""
    B, S = k.shape[:2]
    page = layer.k.shape[1]
    np_ = layer.k.shape[0]
    P = page_table.shape[1]
    if (_DENSE_PREFILL and not ring and pos0 == 0 and np_ == B * P
            and S <= P * page):
        # canonical layout: page id = b·P + j  →  the heap IS the
        # reshaped K/V tensor (zero-scatter path).
        pad = P * page - S
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = kp.reshape(np_, page, *k.shape[2:])
        vp = vp.reshape(np_, page, *v.shape[2:])
        if layer.k_scale is not None:
            kq, ks = _quant(kp.astype(jnp.float32))
            vq, vs = _quant(vp.astype(jnp.float32))
            return KVLayer(k=kq, v=vq, k_scale=ks, v_scale=vs)
        return KVLayer(k=kp.astype(layer.k.dtype),
                       v=vp.astype(layer.v.dtype),
                       k_scale=None, v_scale=None)
    pos = pos0 + jnp.arange(S, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    pidx, slot = pos // page, pos % page
    if ring:
        pidx = pidx % P
    ids = jnp.take_along_axis(page_table, pidx, axis=1)
    idx = (jnp.where(ids >= 0, ids, np_), slot)
    return _store(layer, idx, k, v)


def paged_attend1(layer: KVLayer, page_table, kv_len, q, *,
                  window: Optional[int] = None, page_block: int = 16,
                  ring: bool = False):
    """Decode attention for one layer over the paged heap.

    q: (B, 1, Hq, hd); kv_len: (B,) valid tokens (incl. current).
    Blockwise online softmax over page-table gathers — O(page_block)
    live memory, GSPMD-shardable (heads on 'model', batch on 'data')."""
    B, _, Hq, D = q.shape
    NP, page, Hkv, _ = layer.k.shape
    P = page_table.shape[1]
    G = Hq // Hkv
    pb = min(_PB_OVERRIDE or page_block, P)
    nblk = -(-P // pb)
    pad = nblk * pb - P
    pt = jnp.pad(page_table, ((0, 0), (0, pad)), constant_values=-1)
    ptb = pt.reshape(B, nblk, pb).transpose(1, 0, 2)   # (nblk, B, pb)

    # staging dtype follows the cache: f32 caches (tests, oracles) stay
    # exact; bf16/int8 caches stage in bf16 (small dequant blocks) with
    # f32 accumulation via preferred_element_type below.
    stage_dt = (jnp.float32 if layer.k.dtype == jnp.float32
                else jnp.bfloat16)
    qg = (q[:, 0].reshape(B, Hkv, G, D) * (D ** -0.5)).astype(stage_dt)

    def body(carry, inp):
        m, l, acc = carry
        i, ids = inp                                   # ids: (B, pb)
        safe = jnp.maximum(ids, 0)
        k = layer.k[safe].astype(stage_dt)             # (B, pb, page, Hkv, D)
        v = layer.v[safe].astype(stage_dt)
        if layer.k_scale is not None:
            k = k * layer.k_scale[safe][..., None].astype(stage_dt)
            v = v * layer.v_scale[safe][..., None].astype(stage_dt)
        k = k.reshape(B, pb * page, Hkv, D)
        v = v.reshape(B, pb * page, Hkv, D)
        j = i * pb + jax.lax.broadcasted_iota(jnp.int32, (pb, page), 0)
        slot_of = jax.lax.broadcasted_iota(jnp.int32, (pb, page), 1)
        if ring:
            # ring table: slot j holds absolute page cur − ((cur−j) mod P)
            cur = (jnp.maximum(kv_len, 1) - 1)[:, None, None] // page
            abs_page = cur - ((cur - j[None]) % P)
            tok = (abs_page * page + slot_of[None]).reshape(B, -1)
            valid = (tok >= 0) & (tok < kv_len[:, None]) \
                & jnp.repeat(ids >= 0, page, axis=1)
        else:
            tok = (j * page + slot_of).reshape(-1)[None]  # absolute positions
            valid = (tok < kv_len[:, None]) \
                & jnp.repeat(ids >= 0, page, axis=1)
        if window is not None:
            valid &= tok > (kv_len[:, None] - 1 - window)
        s = jnp.einsum("bhgd,bthd->bhgt", qg, k,
                       preferred_element_type=jnp.float32)
        s = jnp.where(valid[:, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(valid[:, None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        acc_new = alpha[..., None] * acc + jnp.einsum(
            "bhgt,bthd->bhgd", p.astype(stage_dt), v,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, D), jnp.float32)
    from repro.models.layers import scan_unroll
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nblk, dtype=jnp.int32), ptb),
        unroll=(min(nblk, 8) if scan_unroll() else 1))
    out = acc / (l[..., None] + 1e-30)
    return out.reshape(B, 1, Hq, D)
