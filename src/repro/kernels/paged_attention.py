"""Pallas kernel: decode attention over an Ouroboros-paged KV heap.

This is where the paper's technique meets the serving path: KV cache
pages are allocated per-sequence from the core allocator (paged/
kv_cache.py) and addressed through a page table.  The kernel walks a
sequence's pages with the page table in **scalar prefetch**, so the
BlockSpec index_map can point each grid step's DMA at the right heap
page — dynamic memory indirection at DMA-issue time, the TPU analogue
of the GPU allocator's pointer chase, with no gather on the vector unit.

Grid: (batch, kv_heads, pages) — pages innermost, online-softmax
accumulators live in VMEM scratch across page steps (flash-attention
style).  GQA folds query heads into a (G, D) tile per kv head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    i = pl.program_id(2)
    npages = pl.num_programs(2)
    page = k_ref.shape[1]
    scale = 1.0 / (q_ref.shape[-1] ** 0.5)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (page, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    tok = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = (tok < sl_ref[b]) & (pt_ref[b, i] >= 0)  # (1, page)
    s = jnp.where(valid, s, _NEG)

    m_old = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_old, s.max(axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)   # (G, page)
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == npages - 1)
    def _emit():
        o_ref[...] = (acc_ref[...] / (l_ref[...] + 1e-30))[None, None]


@functools.partial(jax.jit, static_argnames=("interpret", "wpp"))
def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    interpret: bool = False, wpp: int | None = None):
    """q: (B, Hq, D); {k,v}_pages: (NP, page, Hkv, D);
    page_table: (B, P) int32 (−1 = hole); seq_lens: (B,) int32.
    Returns (B, Hq, D) float32.

    ``wpp`` (words per page): when set, ``page_table`` holds raw arena
    WORD offsets exactly as the allocator granted them — the decode
    mega-step path where grants scatter into the device table with no
    host round-trip.  The page id is derived (``offset // wpp``) inside
    the scalar-prefetch index map, i.e. at DMA-issue time, so the
    kernel reads the allocator's own words directly (holes stay −1
    under floor division)."""
    B, Hq, D = q.shape
    NP, page, Hkv, _ = k_pages.shape
    P = page_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)

    def kv_map(b, h, i, pt, sl):
        pid = pt[b, i] if wpp is None else pt[b, i] // wpp
        return (jnp.maximum(pid, 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, i, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, i, pt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
