"""Pallas kernel: ring-buffer window gather (the page-alloc hot path).

Because lane-aggregated grants are rank-dense per class (DESIGN.md §2),
a bulk dequeue of ``counts[c]`` pages is a *contiguous* window of the
class's ring starting at ``front[c]`` — so the TPU formulation needs no
scatter/gather at all: one wrapped dynamic slice per class row, staged
through VMEM.  ``front``/``counts`` ride in as scalar prefetch so the
slice start is known before the DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(front_ref, counts_ref, store_ref, out_ref):
    c = pl.program_id(0)
    m = out_ref.shape[1]
    row = store_ref[0, :]
    # Double the row in VMEM so any wrapped window is one dynamic slice.
    padded = jnp.concatenate([row, row[:m]])
    cap = row.shape[0]
    start = front_ref[c] % cap
    win = jax.lax.dynamic_slice(padded, (start,), (m,))
    j = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    out_ref[...] = jnp.where(j < counts_ref[c], win[None, :], -1)


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def ring_window(store, front, counts, *, m: int, interpret: bool = False):
    """out[c, j] = store[c, (front[c]+j) % cap] for j < counts[c] else -1."""
    C, cap = store.shape
    if m > cap:
        raise ValueError(f"window {m} exceeds ring capacity {cap}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(C,),
        in_specs=[pl.BlockSpec((1, cap), lambda c, f, n: (c, 0))],
        out_specs=pl.BlockSpec((1, m), lambda c, f, n: (c, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, m), store.dtype),
        interpret=interpret,
    )(front.astype(jnp.int32), counts.astype(jnp.int32), store)
