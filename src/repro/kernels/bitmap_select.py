"""Pallas kernel: bitmap rank-select (chunk-allocator page scan).

"first obtaining a chunk index, then scanning the chunk for free pages"
(paper §4.2) — the GPU original scans the occupancy bitmap per thread
with ``__ffs`` loops.  The TPU formulation expands each 32-bit word into
a (words, 32) bit tile in VMEM, ranks set bits with a running prefix
carried across sequential grid steps in SMEM, and emits a dense
rank-or-(−1) map; compaction to indices happens in the wrapper (scatter
is cheap in XLA, painful on the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(k_ref, words_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0

    words = words_ref[...].astype(jnp.uint32)  # (bw,)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (words.shape[0], 32), 1)
    bits = ((words[:, None] >> shifts) & 1).astype(jnp.int32)
    flat = bits.reshape(-1)
    prefix = jnp.cumsum(flat) - flat
    rank = carry_ref[0] + prefix
    sel = (flat == 1) & (rank < k_ref[0])
    out_ref[...] = jnp.where(sel, rank, -1)
    carry_ref[0] += jnp.sum(flat)


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def bitmap_select(words, k, *, block_words: int = 32,
                  interpret: bool = False):
    """Dense rank map of set bits: rank if rank < k else -1 (per bit)."""
    (w,) = words.shape
    if w % block_words:
        raise ValueError(f"bitmap words {w} % block {block_words} != 0")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w // block_words,),
        in_specs=[pl.BlockSpec((block_words,), lambda i, k: (i,))],
        out_specs=pl.BlockSpec((block_words * 32,), lambda i, k: (i,)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w * 32,), jnp.int32),
        interpret=interpret,
    )(jnp.asarray([k], jnp.int32), words)
