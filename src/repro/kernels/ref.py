"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

Each function is the semantic ground truth the kernels are allclose-
tested against (tests/test_kernels.py sweeps shapes & dtypes).
"""
from __future__ import annotations

import jax.numpy as jnp


def ring_window_ref(store, front, counts, m):
    """out[c, j] = store[c, (front[c]+j) % cap] for j < counts[c], else -1.

    The page-allocator hot path: each class's grant is a contiguous ring
    window (ranks are dense), so the bulk dequeue is a wrapped slice."""
    C, cap = store.shape
    j = jnp.arange(m, dtype=jnp.int32)[None, :]
    pos = (front[:, None] + j) % cap
    vals = jnp.take_along_axis(store, pos, axis=1)
    return jnp.where(j < counts[:, None], vals, -1).astype(store.dtype)


def bitmap_select_ref(words, k):
    """Dense rank-select over a bitmap: for each bit position, its rank
    among set bits if that rank < k, else -1.  (words: (W,) uint32)."""
    bits = ((words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & 1
            ).reshape(-1).astype(jnp.int32)
    rank = jnp.cumsum(bits) - bits
    sel = (bits == 1) & (rank < k)
    return jnp.where(sel, rank, -1).astype(jnp.int32)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Decode attention over a paged KV heap.

    q:          (B, Hq, D)
    k_pages:    (NP, page, Hkv, D)   — allocator-managed page heap
    v_pages:    (NP, page, Hkv, D)
    page_table: (B, P) int32         — page ids per sequence, -1 = unused
    seq_lens:   (B,) int32           — tokens in cache per sequence
    returns:    (B, Hq, D) float32
    """
    B, Hq, D = q.shape
    NP, page, Hkv, _ = k_pages.shape
    P = page_table.shape[1]
    G = Hq // Hkv

    pt = jnp.where(page_table >= 0, page_table, 0)
    k = k_pages[pt]  # (B, P, page, Hkv, D)
    v = v_pages[pt]
    k = k.reshape(B, P * page, Hkv, D).astype(jnp.float32)
    v = v.reshape(B, P * page, Hkv, D).astype(jnp.float32)

    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bthd->bhgt", qf, k) / jnp.sqrt(D)
    t = jnp.arange(P * page, dtype=jnp.int32)[None, :]
    valid = (t < seq_lens[:, None]) & (page_table >= 0).repeat(page, axis=1)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / (p.sum(axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return out.reshape(B, Hq, D)


def ssd_ref(x, dt, a, b, c, h0=None):
    """Mamba-2 SSD, naive sequential recurrence (the oracle).

    x:  (B, L, H, P)  — inputs per head
    dt: (B, L, H)     — positive step sizes
    a:  (H,)          — negative decay rates (A = -exp(a_log))
    b:  (B, L, G, N)  — input projection (G groups, H % G == 0)
    c:  (B, L, G, N)  — output projection
    h0: (B, H, P, N)  — optional initial state
    returns: y (B, L, H, P), h_final (B, H, P, N)
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)  # (B, L, H, N)
    ch = jnp.repeat(c, rep, axis=2)
    h = (jnp.zeros((B, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for t in range(L):
        decay = jnp.exp(dt[:, t] * a[None, :])  # (B, H)
        h = (h * decay[:, :, None, None]
             + (dt[:, t, :, None] * x[:, t]).astype(jnp.float32)[..., None]
             * bh[:, t, :, None, :].astype(jnp.float32))
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, ch[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), h
