"""Pallas kernels: fused allocator transactions (the device-side fast path).

The paper's point is that the allocator itself runs *on device*; the
jnp reference path (core/{queues,page_alloc,chunk_alloc}.py) is correct
but lowers as a long chain of XLA gathers/scatters.  These kernels fuse
one whole bulk transaction into a single ``pallas_call`` per family:

``ring_txn_pop``  — the page-family alloc transaction: per-class masked
    rank (``groups.masked_rank`` moved in-kernel), inventory grant,
    wrapped ring-window fetch, value gather, and the aggregated
    front-counter advance.  One grid step per size class; the class's
    ring row stages through VMEM, ``front``/``back`` ride in as scalar
    prefetch (known before the DMA, exactly like kernels/ring_window).

``ring_txn_push`` — the inverse free transaction: in-kernel rank, a
    rank→slot scatter built as a one-hot reduction (no XLA scatter),
    wrapped window write-back via doubled-row dynamic slices, and the
    back-counter advance.

``chunk_txn_claim`` — the chunk-family claim step: bitmap expansion,
    free-page rank-select (kernels/bitmap_select logic moved on-device),
    bit claim, and the free-count delta, fused into one kernel over a
    chunk's occupancy-bitmap row.

``arena_alloc_txn`` / ``arena_free_txn`` — the arena-era full fusion:
    ONE ``pallas_call`` executes an *entire* bulk transaction for any of
    the six variants against the flat device-resident arena
    (core/arena.py): masked rank, inventory grant, ring pop/push, the
    chunk-bitmap claim loop, and — for the virtualized families — the
    whole va/vl segment walk (directory chase / next-pointer chain,
    segment grow/shrink via the chunk pool) that PR 1 still composed as
    host-built jnp ops around the piecewise kernels above.  The kernel
    body IS the shared transaction math (core/transactions.alloc_math /
    free_math) applied to the ``mem``/``ctl`` refs, so parity with the
    jnp oracle is structural rather than re-implemented; ``mem``/``ctl``
    are input/output-aliased, making the transaction an in-place update
    of device state.  The piecewise kernels remain as independently
    tested building blocks (tests/test_kernels.py).

Mechanism mapping (DESIGN.md §4): GPU Ouroboros mutates ``front``/
``back`` with per-thread atomics inside a warp-aggregated critical
section; here the whole request vector is one grid program, the rank
computation plays the role of the warp ballot, and the single counter
write at the end is the aggregated atomic-add.  Equivalence with the
jnp path is bit-exact (int32 arithmetic only) and enforced by
tests/test_alloc_txn_parity.py.

On CPU (tests, CI) the kernels run in ``interpret=True`` mode; the
wrappers in kernels/ops.py pick the mode from the backend.  VMEM note:
the ring row (``cap`` words), the lane vector (``n``), and the (n, m)
one-hot tile must fit on-chip — callers keep ``n`` at bulk-transaction
width (≤ 8K lanes), as everywhere else in this repo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota(n):
    """1-D iota via 2-D broadcasted_iota (TPU forbids 1-D iota)."""
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)


def _member_rank(cls, valid, c):
    """In-kernel ``groups.masked_rank`` restricted to class ``c``."""
    member = (cls == c) & (valid != 0)
    memi = member.astype(jnp.int32)
    inc = jnp.cumsum(memi)
    return member, inc - memi, jnp.sum(memi)


# --------------------------------------------------------------------------
# ring_txn_pop — fused page-family alloc
# --------------------------------------------------------------------------

def _pop_kernel(front_ref, back_ref, store_ref, cls_ref, valid_ref,
                vals_ref, nfront_ref, *, m: int, limit: bool):
    c = pl.program_id(0)
    row = store_ref[0, :]
    n = cls_ref.shape[0]

    member, rank, _ = _member_rank(cls_ref[...], valid_ref[...], c)
    if limit:
        # inventory grant: the dense rank prefix that fits back - front
        grant = member & (rank < back_ref[c] - front_ref[c])
    else:
        grant = member
    cnt = jnp.sum(grant.astype(jnp.int32))

    # wrapped window [front, front + m) as one dynamic slice of the
    # doubled row, then a one-hot gather (granted ranks are dense, and
    # rank % m == wrapped ring position relative to the window start).
    start = front_ref[c] % row.shape[0]
    padded = jnp.concatenate([row, row[:m]])
    win = jax.lax.dynamic_slice(padded, (start,), (m,))
    j = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    sel = grant[:, None] & (j == (rank % m)[:, None])
    gathered = jnp.sum(jnp.where(sel, win[None, :], 0), axis=1)

    @pl.when(c == 0)
    def _init():
        vals_ref[...] = jnp.full((n,), -1, jnp.int32)

    vals_ref[...] = jnp.where(grant, gathered, vals_ref[...])
    nfront_ref[0] = front_ref[c] + cnt


@functools.partial(jax.jit, static_argnames=("limit", "interpret"))
def ring_txn_pop(store, front, back, cls, valid, *, limit: bool,
                 interpret: bool = False):
    """Fused bulk dequeue.  Returns ``(vals, new_front)``.

    ``limit=True`` is the page-alloc transaction (lanes whose in-class
    rank exceeds inventory fail with −1 and do not advance the
    counter); ``limit=False`` replicates ``queues.ring_bulk_dequeue``
    exactly (unconditional pop — pool semantics).  Lanes' implicit rank
    is their in-kernel masked rank, which matches every call site's
    ``groups.masked_rank``.
    """
    C, cap = store.shape
    n = cls.shape[0]
    m = min(n, cap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(C,),
        in_specs=[pl.BlockSpec((1, cap), lambda c, f, b: (c, 0)),
                  pl.BlockSpec((n,), lambda c, f, b: (0,)),
                  pl.BlockSpec((n,), lambda c, f, b: (0,))],
        out_specs=[pl.BlockSpec((n,), lambda c, f, b: (0,)),
                   pl.BlockSpec((1,), lambda c, f, b: (c,))],
    )
    vals, new_front = pl.pallas_call(
        functools.partial(_pop_kernel, m=m, limit=limit),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((C,), jnp.int32)],
        interpret=interpret,
    )(front.astype(jnp.int32), back.astype(jnp.int32), store,
      cls.astype(jnp.int32), valid.astype(jnp.int32))
    return vals, new_front


# --------------------------------------------------------------------------
# ring_txn_push — fused page-family free
# --------------------------------------------------------------------------

def _push_kernel(back_ref, store_ref, cls_ref, vals_ref, valid_ref,
                 out_ref, nback_ref, *, m: int):
    c = pl.program_id(0)
    row = store_ref[0, :]
    cap = row.shape[0]
    n = cls_ref.shape[0]

    member, rank, cnt = _member_rank(cls_ref[...], valid_ref[...], c)

    # rank → window-slot scatter as a one-hot reduction over lanes
    # (slots are unique while the ring has room, which init guarantees).
    j2 = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    sel = member[:, None] & (j2 == (rank % m)[:, None])
    w = jnp.sum(jnp.where(sel, vals_ref[...][:, None], 0), axis=0)

    # write w[0:cnt] at ring positions (back + j) % cap: dynamic-update
    # the doubled row, then fold the overflow back onto the head.
    start = back_ref[c] % cap
    padded = jnp.concatenate([row, row[:m]])
    cur = jax.lax.dynamic_slice(padded, (start,), (m,))
    jm = _iota(m)
    padded = jax.lax.dynamic_update_slice(
        padded, jnp.where(jm < cnt, w, cur), (start,))
    over = start + cnt - cap
    head = jnp.where(jm < over, padded[cap:cap + m], padded[:m])
    out_ref[0, :] = jnp.concatenate([head, padded[m:cap]])
    nback_ref[0] = back_ref[c] + cnt


@functools.partial(jax.jit, static_argnames=("interpret",))
def ring_txn_push(store, back, cls, vals, valid, *,
                  interpret: bool = False):
    """Fused bulk enqueue.  Returns ``(new_store, new_back)`` —
    bit-identical to ``queues.ring_bulk_enqueue``."""
    C, cap = store.shape
    n = cls.shape[0]
    m = min(n, cap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[pl.BlockSpec((1, cap), lambda c, b: (c, 0)),
                  pl.BlockSpec((n,), lambda c, b: (0,)),
                  pl.BlockSpec((n,), lambda c, b: (0,)),
                  pl.BlockSpec((n,), lambda c, b: (0,))],
        out_specs=[pl.BlockSpec((1, cap), lambda c, b: (c, 0)),
                   pl.BlockSpec((1,), lambda c, b: (c,))],
    )
    new_store, new_back = pl.pallas_call(
        functools.partial(_push_kernel, m=m),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((C, cap), store.dtype),
                   jax.ShapeDtypeStruct((C,), jnp.int32)],
        interpret=interpret,
    )(back.astype(jnp.int32), store, cls.astype(jnp.int32),
      vals.astype(jnp.int32), valid.astype(jnp.int32))
    return new_store, new_back


# --------------------------------------------------------------------------
# chunk_txn_claim — fused chunk-family bitmap claim
# --------------------------------------------------------------------------

def _claim_kernel(take_ref, row_ref, pidx_ref, nrow_ref, nsel_ref,
                  *, ppc: int):
    row = row_ref[...].astype(jnp.uint32)
    bw = row.shape[0]
    nbits = bw * 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, 32), 1)
    occ = ((row[:, None] >> shifts) & 1).astype(jnp.int32).reshape(nbits)
    p = _iota(nbits)
    free = (occ == 0) & (p < ppc)
    fi = free.astype(jnp.int32)
    order = jnp.cumsum(fi) - fi
    chosen = free & (order < take_ref[0])
    total = jnp.sum(chosen.astype(jnp.int32))

    # compact chosen bit positions to the front (ascending, −1 padded),
    # matching jnp.nonzero(chosen, size=nbits, fill_value=-1)
    onehot = chosen[None, :] & (order[None, :] == p[:, None])
    pidx = jnp.sum(jnp.where(onehot, p[None, :], 0), axis=1)
    pidx_ref[...] = jnp.where(p < total, pidx, -1)

    add = jnp.sum(jnp.where(chosen.reshape(bw, 32),
                            jnp.uint32(1) << shifts, jnp.uint32(0)), axis=1)
    nrow_ref[...] = row + add  # claimed bits were 0, so + == OR
    nsel_ref[0] = total


@functools.partial(jax.jit, static_argnames=("ppc", "interpret"))
def chunk_txn_claim(row, take, *, ppc: int, interpret: bool = False):
    """Fused rank-select + claim over one chunk's occupancy bitmap.

    Returns ``(page_idx, new_row, n_selected)``: the first ``take``
    free page indices (−1 padded, ascending — bit-identical to
    ``chunk_alloc._select_free_pages``), the bitmap row with those bits
    set, and the claimed count (== the free-count delta).
    """
    (bw,) = row.shape
    nbits = bw * 32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec((bw,), lambda i, t: (0,))],
        out_specs=[pl.BlockSpec((nbits,), lambda i, t: (0,)),
                   pl.BlockSpec((bw,), lambda i, t: (0,)),
                   pl.BlockSpec((1,), lambda i, t: (0,))],
    )
    return pl.pallas_call(
        functools.partial(_claim_kernel, ppc=ppc),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nbits,), jnp.int32),
                   jax.ShapeDtypeStruct((bw,), jnp.uint32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(jnp.reshape(take, (1,)).astype(jnp.int32), row)


# --------------------------------------------------------------------------
# arena_alloc_txn / arena_free_txn — one kernel per whole transaction
# --------------------------------------------------------------------------
#
# The kernel body loads the full mem/ctl images once, runs the shared
# transaction math (core/transactions), and stores the new images —
# counters, ring words, directory entries, bitmaps, and the heap words
# the va/vl segment walk touches all mutate inside the single kernel.
# ``input_output_aliases`` pins mem/ctl in place, so on device the call
# is an in-place arena update with no state round trip.  The one-kernel
# property is asserted on the lowered jaxpr by
# tests/test_alloc_txn_parity.py::test_single_pallas_call_per_txn.

@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_alloc_txn(cfg, kind, family, mem, ctl, sizes_bytes, mask, *,
                    interpret: bool = False):
    """Fused whole-transaction alloc for any (kind, family) variant.

    Returns ``(new_mem, new_ctl, offsets)`` — bit-identical to
    ``transactions.alloc_math`` (the jnp oracle), which is also the
    kernel body."""
    from repro.core import transactions  # lazy: kernels <-> core

    n = sizes_bytes.shape[0]

    def kernel(mem_ref, ctl_ref, sizes_ref, valid_ref,
               omem_ref, octl_ref, offs_ref):
        nm, nc, offs = transactions.alloc_math(
            cfg, kind, family, mem_ref[...], ctl_ref[...],
            sizes_ref[...], valid_ref[...] != 0)
        omem_ref[...] = nm
        octl_ref[...] = nc
        offs_ref[...] = offs

    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(mem.shape, jnp.int32),
                   jax.ShapeDtypeStruct(ctl.shape, jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_free_txn(cfg, kind, family, mem, ctl, offsets_words,
                   sizes_bytes, mask, *, interpret: bool = False):
    """Fused whole-transaction free.  Returns ``(new_mem, new_ctl)``."""
    from repro.core import transactions  # lazy: kernels <-> core

    def kernel(mem_ref, ctl_ref, offs_ref, sizes_ref, valid_ref,
               omem_ref, octl_ref):
        nm, nc = transactions.free_math(
            cfg, kind, family, mem_ref[...], ctl_ref[...],
            offs_ref[...], sizes_ref[...], valid_ref[...] != 0)
        omem_ref[...] = nm
        octl_ref[...] = nc

    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(mem.shape, jnp.int32),
                   jax.ShapeDtypeStruct(ctl.shape, jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, offsets_words.astype(jnp.int32),
      sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))


# --------------------------------------------------------------------------
# sharded whole-lowering: the (attempt, shard) schedule as one grid
# --------------------------------------------------------------------------
#
# One pallas_call per sharded transaction (core/shards.py, DESIGN.md
# §9).  The grid is (walk+1, num_shards) for alloc — step (a, s) runs
# the full single-arena transaction math on shard s's slab for the
# still-unserved lanes whose (home + a) % S == s, exactly the serial
# replay order of transactions.sharded_alloc_math — and (num_shards,)
# for free (an offset lives on exactly one shard).  Shard slabs stage
# through BlockSpec row selection; the offsets vector is a
# grid-persistent accumulator block (constant index map) whose −1
# lanes mark "still unserved" for later attempts.  mem/ctl are
# input/output-aliased as in the single-arena kernels; outputs are
# staged from the inputs on each shard's FIRST visit only, so later
# attempts see the earlier attempts' updates.

@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "walk", "interpret"))
def sharded_arena_alloc_txn(cfg, num_shards, kind, family, mem, ctl,
                            sizes_bytes, mask, home, walk, *,
                            interpret: bool = False):
    """Sharded fused alloc: ONE pallas_call gridding the overflow-walk
    schedule over per-shard slabs.  Returns ``(new_mem, new_ctl,
    global_offsets)`` — bit-identical to
    ``transactions.sharded_alloc_math``."""
    from repro.core import shards, transactions  # lazy: kernels <-> core

    S = num_shards
    scfg = shards.shard_config(cfg, S)
    Ws = scfg.total_words
    Mw, Cw = mem.shape[1], ctl.shape[1]
    n = sizes_bytes.shape[0]

    def kernel(mem_ref, ctl_ref, sizes_ref, valid_ref, home_ref,
               omem_ref, octl_ref, offs_ref):
        a = pl.program_id(0)
        s = pl.program_id(1)

        @pl.when((a == 0) & (s == 0))
        def _first():
            offs_ref[...] = jnp.full((n,), -1, jnp.int32)

        @pl.when(a == 0)
        def _stage():  # first visit of shard s: boundary state in
            omem_ref[...] = mem_ref[...]
            octl_ref[...] = ctl_ref[...]

        sel = ((valid_ref[...] != 0)
               & ((home_ref[...] + a) % S == s)
               & (offs_ref[...] < 0))
        nm, nc, local = transactions.alloc_math(
            scfg, kind, family, omem_ref[0, :], octl_ref[0, :],
            sizes_ref[...], sel, attempt=a)
        omem_ref[0, :] = nm
        octl_ref[0, :] = nc
        offs_ref[...] = jnp.where(sel & (local >= 0), s * Ws + local,
                                  offs_ref[...])

    lane = pl.BlockSpec((n,), lambda a, s: (0,))
    return pl.pallas_call(
        kernel,
        grid=(walk + 1, S),
        in_specs=[pl.BlockSpec((1, Mw), lambda a, s: (s, 0)),
                  pl.BlockSpec((1, Cw), lambda a, s: (s, 0)),
                  lane, lane, lane],
        out_specs=[pl.BlockSpec((1, Mw), lambda a, s: (s, 0)),
                   pl.BlockSpec((1, Cw), lambda a, s: (s, 0)),
                   pl.BlockSpec((n,), lambda a, s: (0,))],
        out_shape=[jax.ShapeDtypeStruct((S, Mw), jnp.int32),
                   jax.ShapeDtypeStruct((S, Cw), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32),
      home.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "interpret"))
def sharded_arena_free_txn(cfg, num_shards, kind, family, mem, ctl,
                           offsets_words, sizes_bytes, mask, *,
                           interpret: bool = False):
    """Sharded fused free: grid over shards, each step freeing the
    lanes whose global offsets it owns.  Returns ``(new_mem,
    new_ctl)`` — bit-identical to ``transactions.sharded_free_math``."""
    from repro.core import shards, transactions  # lazy: kernels <-> core

    S = num_shards
    scfg = shards.shard_config(cfg, S)
    Ws = scfg.total_words
    Mw, Cw = mem.shape[1], ctl.shape[1]
    n = sizes_bytes.shape[0]

    def kernel(mem_ref, ctl_ref, offs_ref, sizes_ref, valid_ref,
               omem_ref, octl_ref):
        s = pl.program_id(0)
        omem_ref[...] = mem_ref[...]  # each shard is visited once
        octl_ref[...] = ctl_ref[...]
        offs = offs_ref[...]
        sh = jnp.where(offs >= 0, offs // Ws, -1)
        sel = (valid_ref[...] != 0) & (sh == s)
        local = jnp.where(sel, offs - s * Ws, -1)
        nm, nc = transactions.free_math(
            scfg, kind, family, omem_ref[0, :], octl_ref[0, :], local,
            sizes_ref[...], sel)
        omem_ref[0, :] = nm
        octl_ref[0, :] = nc

    lane = pl.BlockSpec((n,), lambda s: (0,))
    return pl.pallas_call(
        kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, Mw), lambda s: (s, 0)),
                  pl.BlockSpec((1, Cw), lambda s: (s, 0)),
                  lane, lane, lane],
        out_specs=[pl.BlockSpec((1, Mw), lambda s: (s, 0)),
                   pl.BlockSpec((1, Cw), lambda s: (s, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, Mw), jnp.int32),
                   jax.ShapeDtypeStruct((S, Cw), jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, offsets_words.astype(jnp.int32),
      sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))
