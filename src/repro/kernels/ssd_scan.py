"""Pallas kernel: Mamba-2 SSD chunked scan (state-space duality).

The assigned mamba2-780m architecture's hot spot.  The chunked dual
form turns the sequential SSM recurrence into MXU-friendly matmuls:
within a chunk of Q tokens the output is a masked (Q, Q) "attention"
against decay weights; across chunks a (P, N) state is carried in VMEM
scratch through the sequential innermost grid dimension.

All decay exponents are non-positive (a < 0, dt > 0) so every exp() is
≤ 1 — numerically safe in f32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
            y_ref, hout_ref, h_ref):
    i = pl.program_id(2)
    nchunks = pl.num_programs(2)
    q = x_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)          # scalar
    bm = b_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)

    dta = dt * a
    cum = jnp.cumsum(dta)                        # (Q,)
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    w = jnp.where(row >= col, decay, 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = jax.lax.dot((cb * w) * dt[None, :], x,
                    preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(cum_i) C_i^T h_in
    h = h_ref[...]                               # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[...] = y[None, :, None, :]
    # state: h_out = exp(cum_Q) h_in + sum_j exp(cum_Q - cum_j) dt_j x_j b_j^T
    wj = jnp.exp(cum[-1] - cum) * dt             # (Q,)
    h_ref[...] = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        x * wj[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nchunks - 1)
    def _emit():
        hout_ref[...] = h_ref[...][None, None]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, h0=None, *, chunk: int = 64,
             interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, G, N);
    h0: (B, H, P, N) or None.  Returns (y (B, L, H, P) f32,
    h_final (B, H, P, N) f32)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    if L % chunk:
        raise ValueError(f"L={L} not a multiple of chunk={chunk}")
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, H, L // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, i: (bi, i, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, h, i: (bi, i, h)),
            pl.BlockSpec((1, 1), lambda bi, h, i: (h, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bi, h, i: (bi, i, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda bi, h, i: (bi, i, h // rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, i: (bi, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, i: (bi, i, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, i: (bi, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
    )
    y, hf = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a.reshape(H, 1), b, c, h0)
    return y, hf
