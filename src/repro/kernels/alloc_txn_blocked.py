"""Region-blocked compiled lowering for the fused arena transactions.

``kernels/alloc_txn.arena_*_txn`` (the ``whole`` lowering) hands the
kernel the entire ``mem`` word image as one ref — correct, and ideal
for interpret mode, but it only lowers to a real compiled TPU kernel
while the whole arena fits VMEM.  This module is the serving-scale
story: the *same* transactions as ONE ``pallas_call`` whose refs are
driven by the :class:`~repro.core.arena.ArenaLayout` region table
(DESIGN.md §8):

- the grid iterates the **size classes**; step ``c`` stages only class
  ``c``'s queue-ring row (or segment-directory row) through VMEM via a
  ``BlockSpec`` index map — never the whole queue region;
- the **control block rides as scalar prefetch** (its counters feed
  loop bounds and DMA addresses) and is accumulated across grid steps
  in a VMEM-resident output block;
- small metadata regions (chunk pool ring, free counts, chunk→class
  bindings) are **VMEM-resident** blocks with constant index maps —
  fetched once, revisited in place;
- the **heap** and the **chunk bitmaps** never enter VMEM wholesale:
  they stay in HBM (``memory_space=ANY``) and the kernel reads/writes
  only the touched words — segment slots, next pointers, one bitmap
  row per claimed chunk — through dynamic loads/stores;
- regions a transaction cannot write (``Region.blocking ==
  "untouched"``) bypass the kernel entirely.

The transaction math is the per-class / per-region decomposition of
``core/transactions.alloc_math``/``free_math``: every body below
mirrors one oracle path (``page_alloc``/``chunk_alloc`` over
``queues``) at row/scalar granularity, and the differential harness
(tests/test_alloc_txn_parity.py) holds all three implementations —
jnp oracle, whole lowering, blocked lowering — bit-identical on
randomized traces, word for word across the arena.

Predication convention: Pallas has no masked scatter, so conditional
single-word effects are read-modify-writes at a safe address —
``addr = where(cond, addr, 0)`` then ``store(where(cond, new, old))``
— which is exactly a no-op when ``cond`` is false.  Grid steps execute
sequentially, so read-after-write across steps (pool counters, pool
ring words, heap pointers) is well-defined; the cross-class orders
below (class-major pool pops/pushes) replicate the oracle's flattened
scatter orders.

Mosaic portability note: every HBM(ANY)-ref access goes through the
``_ld``/``_st``/``_vec_ld``/``_vec_st_if`` vocabulary below, which
interpret mode executes as direct dynamic loads/stores.  A compiled
Mosaic build that insists on explicit DMA for ANY-space refs needs
exactly these four helpers rewritten over ``pltpu.make_async_copy``
scratch staging — the kernel bodies never touch an HBM ref directly,
so that swap is local and the word-level access pattern (the §8
contract) is already the DMA shape.  Validating the blocked lowering
on real TPU silicon is the ROADMAP's named next step; everything
CI-visible runs it in interpret mode, which pins the semantics the
compiled build must reproduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import arena
from repro.core.heap import size_to_class_device
from repro.kernels.alloc_txn import _iota, _member_rank

NULL = -1


# --------------------------------------------------------------------------
# scalar / row staging helpers (the DMA vocabulary of the blocked kernels)
# --------------------------------------------------------------------------

class _ShardView:
    """A flat HBM(ANY) ref addressed at a per-shard base offset.

    The sharded wrapper (``_txn_call_sharded``) keeps hbm regions as
    ONE flat (S · words) ref shared by every grid step and hands the
    per-class bodies a ``_ShardView(ref, s · words)`` instead; the
    helpers below unwrap it by adding ``base`` to every address, so the
    bodies stay byte-for-byte identical between the single-arena and
    the sharded blocked lowering."""
    __slots__ = ("ref", "base")

    def __init__(self, ref, base):
        self.ref = ref
        self.base = base


def _at(ref, i):
    """Resolve (ref, index) through an optional :class:`_ShardView`."""
    if isinstance(ref, _ShardView):
        return ref.ref, ref.base + i
    return ref, i


def _ld(ref, i):
    """Dynamic scalar load from a flat ref."""
    ref, i = _at(ref, i)
    return pl.load(ref, (pl.ds(i, 1),))[0]


def _st(ref, i, v):
    """Dynamic scalar store to a flat ref."""
    ref, i = _at(ref, i)
    pl.store(ref, (pl.ds(i, 1),),
             jnp.reshape(v, (1,)).astype(ref.dtype))


def _ld_if(ref, i, cond, fill=NULL):
    """Predicated scalar load: ``ref[i] if cond else fill`` (reads a
    safe address when masked, mirroring the oracle's fill-gather)."""
    ref, a = _at(ref, jnp.where(cond, i, 0))
    v = pl.load(ref, (pl.ds(a, 1),))[0]
    return jnp.where(cond, v, fill)


def _st_if(ref, i, v, cond):
    """Predicated scalar store as a safe-address read-modify-write
    (the in-kernel form of the oracle's ``.set(..., mode="drop")``)."""
    ref, a = _at(ref, jnp.where(cond, i, 0))
    old = pl.load(ref, (pl.ds(a, 1),))
    pl.store(ref, (pl.ds(a, 1),),
             jnp.where(cond, jnp.reshape(v, (1,)).astype(old.dtype), old))


def _row_ld(ref, j):
    """Dynamic scalar load from a (1, w) row block."""
    return pl.load(ref, (pl.ds(0, 1), pl.ds(j, 1)))[0, 0]


def _row_st_if(ref, j, v, cond):
    a = jnp.where(cond, j, 0)
    old = pl.load(ref, (pl.ds(0, 1), pl.ds(a, 1)))
    pl.store(ref, (pl.ds(0, 1), pl.ds(a, 1)),
             jnp.where(cond, jnp.reshape(v, (1, 1)).astype(old.dtype),
                       old))


def _vec_ld(ref, start, length):
    """Dynamic row load (``length`` static) from a flat HBM ref."""
    ref, start = _at(ref, start)
    return pl.load(ref, (pl.ds(start, length),))


def _vec_st(ref, start, vals):
    """Dynamic row store to a flat HBM ref."""
    ref, start = _at(ref, start)
    pl.store(ref, (pl.ds(start, vals.shape[0]),), vals.astype(ref.dtype))


def _vec_st_if(ref, start, vals, cond):
    """Predicated row store to a flat HBM ref (safe-address RMW)."""
    ref, a = _at(ref, jnp.where(cond, start, 0))
    old = pl.load(ref, (pl.ds(a, vals.shape[0]),))
    pl.store(ref, (pl.ds(a, vals.shape[0]),),
             jnp.where(cond, vals.astype(old.dtype), old))


def _take(vec, i):
    """Scalar ``vec[i]`` for a traced index into an in-register vector."""
    return jax.lax.dynamic_index_in_dim(vec, i, keepdims=False)


def _gather_small(vec, idx):
    """Per-lane gather from a small in-register vector via one-hot
    (compiled-TPU-friendly: no dynamic gather), OOB lanes read 0."""
    n, K = idx.shape[0], vec.shape[0]
    oh = idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (n, K), 1)
    return jnp.sum(jnp.where(oh, vec[None, :], 0), axis=1)


def _lane_prep(cfg, sizes, valid_i32, offsets=None):
    """The dispatcher's lane prep: class ids and the validity mask
    (``page_alloc``/``chunk_alloc`` preamble, bit for bit)."""
    C = cfg.num_classes
    cls = size_to_class_device(cfg, sizes)
    valid = (valid_i32 != 0) & (cls < C)
    if offsets is not None:
        valid = valid & (offsets >= 0)
    return cls, valid


# --------------------------------------------------------------------------
# chunk-pool ring: scalar pop/push against the VMEM-resident pool row
# --------------------------------------------------------------------------

def _pool_pop1(octl, pool_ref, lay, cond):
    """One predicated pool pop (``queues.pool_dequeue`` semantics: the
    slot is read at the wrapped front, masked lanes yield NULL, the
    counter advances only for active lanes)."""
    nc = pool_ref.shape[0]
    pf = _ld(octl, lay.off_pool_front)
    v = _ld_if(pool_ref, pf % nc, cond, NULL)
    _st(octl, lay.off_pool_front, pf + jnp.where(cond, 1, 0))
    return v


def _pool_push1(octl, pool_ref, lay, v, cond):
    """One predicated pool push (``queues.pool_enqueue`` semantics)."""
    nc = pool_ref.shape[0]
    pb = _ld(octl, lay.off_pool_back)
    _st_if(pool_ref, pb % nc, v, cond)
    _st(octl, lay.off_pool_back, pb + jnp.where(cond, 1, 0))


# --------------------------------------------------------------------------
# segment grow: the one canonical protocol per virtualized family
# --------------------------------------------------------------------------

def _va_grow(octl, pool_ref, dir_ref, lay, spc, back, cnt, m):
    """Append directory segments so slots [back, back+cnt) plus the
    next insertion point are all backed (``queues._grow_counts``):
    pool pops in ascending-j order, directory-row writes after the
    current back segment."""
    n_new = (back + cnt) // spc - back // spc
    seg_back = back // spc
    for j in range(m):
        active = j < n_new
        chk = _pool_pop1(octl, pool_ref, lay, active)
        _row_st_if(dir_ref, (seg_back + 1 + j) % lay.max_segs, chk,
                   active)


def _vl_grow(octl, pool_ref, heap_ref, lay, spc, wpc, W, tail, back,
             cnt, m):
    """Pop, terminate, and chain new tail segments, in the oracle's
    scatter order (all terminators, then links in j order; the last
    new chunk keeps its NULL terminator).  Returns ``(new_chunks,
    tail')`` — the value-write phase selects segments from
    ``[tail] + new_chunks``."""
    n_new = (back + cnt) // spc - back // spc
    new_chunks = [_pool_pop1(octl, pool_ref, lay, j < n_new)
                  for j in range(m)]
    for j in range(m):
        w = new_chunks[j] * wpc
        _st_if(heap_ref, w, NULL, (j < n_new) & (w >= 0) & (w < W))
    for j in range(m):
        prev = tail if j == 0 else new_chunks[j - 1]
        w = prev * wpc
        _st_if(heap_ref, w, new_chunks[j],
               (j < n_new) & (w >= 0) & (w < W))
    last = jnp.maximum(n_new - 1, 0)
    cand = _take(jnp.stack(new_chunks), last)
    return new_chunks, jnp.where(n_new > 0, cand, tail)


# --------------------------------------------------------------------------
# page-kind bodies: one vectorized transaction slice per size class
# --------------------------------------------------------------------------
#
# Each body is the class-c slice of the corresponding oracle bulk
# transaction.  `E` maps region name -> the ref the body must operate
# on (the output ref when the region is written, else the input ref);
# `octl` is the VMEM ctl accumulator initialized from the scalar-
# prefetched control block at step 0.

def _page_ring_alloc(cfg, lay, c, sizes, valid_i32, E, octl, offs_ref):
    """Class-c slice of page_alloc.alloc over the ring family: masked
    rank, inventory grant, wrapped ring-window pop (the ring row is the
    staged VMEM block), one front advance."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32)
    n = cls.shape[0]
    row = E["queue_store"][0, :]
    cap = row.shape[0]
    m = min(n, cap)

    member, rank, _ = _member_rank(cls, valid, c)
    front = _ld(octl, lay.off_front + c)
    back = _ld(octl, lay.off_back + c)
    grant = member & (rank < back - front)
    cnt = jnp.sum(grant.astype(jnp.int32))

    start = front % cap
    padded = jnp.concatenate([row, row[:m]])
    win = jax.lax.dynamic_slice(padded, (start,), (m,))
    j = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    sel = grant[:, None] & (j == (rank % m)[:, None])
    gathered = jnp.sum(jnp.where(sel, win[None, :], 0), axis=1)

    offs_ref[...] = jnp.where(grant, gathered, offs_ref[...])
    _st(octl, lay.off_front + c, front + cnt)


def _page_ring_free(cfg, lay, c, offsets, sizes, valid_i32, E, octl):
    """Class-c slice of page_alloc.free over the ring family: rank,
    rank->slot one-hot scatter, wrapped window write-back on the staged
    ring row, one back advance."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32, offsets)
    n = cls.shape[0]
    qrow = E["queue_store"]
    cap = qrow.shape[1]
    m = min(n, cap)
    row = qrow[0, :]

    member, rank, cnt = _member_rank(cls, valid, c)
    back = _ld(octl, lay.off_back + c)

    j2 = jax.lax.broadcasted_iota(jnp.int32, (n, m), 1)
    sel = member[:, None] & (j2 == (rank % m)[:, None])
    w = jnp.sum(jnp.where(sel, offsets[:, None], 0), axis=0)

    start = back % cap
    padded = jnp.concatenate([row, row[:m]])
    cur = jax.lax.dynamic_slice(padded, (start,), (m,))
    jm = _iota(m)
    padded = jax.lax.dynamic_update_slice(
        padded, jnp.where(jm < cnt, w, cur), (start,))
    over = start + cnt - cap
    head = jnp.where(jm < over, padded[cap:cap + m], padded[:m])
    qrow[0, :] = jnp.concatenate([head, padded[m:cap]])
    _st(octl, lay.off_back + c, back + cnt)


def _page_va_alloc(cfg, lay, c, sizes, valid_i32, E, octl, offs_ref):
    """Class-c slice of page_alloc.alloc over the va family: grant,
    per-lane gather through the directory row into heap segment slots,
    then segment shrink (fully consumed segments -> pool)."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32)
    n = cls.shape[0]
    spc = cfg.slots_per_segment("va")
    wpc = cfg.words_per_chunk
    W = cfg.total_words
    max_segs = lay.max_segs
    m = n // spc + 1

    member, rank, _ = _member_rank(cls, valid, c)
    front = _ld(octl, lay.off_front + c)
    back = _ld(octl, lay.off_back + c)
    grant = member & (rank < back - front)
    cnt = jnp.sum(grant.astype(jnp.int32))
    grant_i = grant.astype(jnp.int32)

    dir_ref = E["directory"]
    heap_ref = E["heap"]

    def lane(i, _):
        g = _take(grant_i, i) != 0
        v = front + _take(rank, i)
        seg = _row_ld(dir_ref, (v // spc) % max_segs)
        word = seg * wpc + v % spc
        ok = g & (word >= 0) & (word < W)
        val = _ld_if(heap_ref, word, ok, NULL)
        _st(offs_ref, i, jnp.where(g, val, _ld(offs_ref, i)))
        return 0

    jax.lax.fori_loop(0, n, lane, 0)

    n_free = (front + cnt) // spc - front // spc
    seg_front = front // spc
    for j in range(m):
        freed = _row_ld(dir_ref, (seg_front + j) % max_segs)
        _pool_push1(octl, E["pool_store"], lay, freed, j < n_free)
    _st(octl, lay.off_front + c, front + cnt)


def _page_va_free(cfg, lay, c, offsets, sizes, valid_i32, E, octl):
    """Class-c slice of page_alloc.free over the va family: segment
    grow (pool pops -> directory row), then per-lane value writes into
    heap segment slots through the updated directory."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32, offsets)
    n = cls.shape[0]
    spc = cfg.slots_per_segment("va")
    wpc = cfg.words_per_chunk
    W = cfg.total_words
    max_segs = lay.max_segs
    m = n // spc + 1

    member, rank, cnt = _member_rank(cls, valid, c)
    back = _ld(octl, lay.off_back + c)
    member_i = member.astype(jnp.int32)

    dir_ref = E["directory"]
    heap_ref = E["heap"]

    _va_grow(octl, E["pool_store"], dir_ref, lay, spc, back, cnt, m)

    def lane(i, _):
        g = _take(member_i, i) != 0
        v = back + _take(rank, i)
        seg = _row_ld(dir_ref, (v // spc) % max_segs)
        word = seg * wpc + v % spc
        _st_if(heap_ref, word, _take(offsets, i),
               g & (word >= 0) & (word < W))
        return 0

    jax.lax.fori_loop(0, n, lane, 0)
    _st(octl, lay.off_back + c, back + cnt)


def _page_vl_alloc(cfg, lay, c, sizes, valid_i32, E, octl, offs_ref):
    """Class-c slice of page_alloc.alloc over the vl family: the
    next-pointer chain walk from the head segment, per-lane gathers,
    then shrink (consumed leading segments -> pool, head advances)."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32)
    n = cls.shape[0]
    spc = cfg.slots_per_segment("vl")
    wpc = cfg.words_per_chunk
    W = cfg.total_words
    m = n // spc + 1

    member, rank, _ = _member_rank(cls, valid, c)
    front = _ld(octl, lay.off_front + c)
    back = _ld(octl, lay.off_back + c)
    grant = member & (rank < back - front)
    cnt = jnp.sum(grant.astype(jnp.int32))
    grant_i = grant.astype(jnp.int32)
    heap_ref = E["heap"]

    head = _ld(octl, lay.off_head + c)
    chain = [head]
    for _hop in range(m):
        prev = chain[-1]
        chain.append(_ld_if(heap_ref, prev * wpc, prev >= 0, NULL))
    chain_vec = jnp.stack(chain)

    def lane(i, _):
        g = _take(grant_i, i) != 0
        v = front + _take(rank, i)
        seg = _take(chain_vec, v // spc - front // spc)
        word = seg * wpc + 1 + v % spc
        ok = g & (word >= 0) & (word < W)
        val = _ld_if(heap_ref, word, ok, NULL)
        _st(offs_ref, i, jnp.where(g, val, _ld(offs_ref, i)))
        return 0

    jax.lax.fori_loop(0, n, lane, 0)

    n_free = (front + cnt) // spc - front // spc
    for j in range(m):
        _pool_push1(octl, E["pool_store"], lay, chain[j], j < n_free)
    _st(octl, lay.off_head + c, _take(chain_vec, n_free))
    _st(octl, lay.off_front + c, front + cnt)


def _page_vl_free(cfg, lay, c, offsets, sizes, valid_i32, E, octl):
    """Class-c slice of page_alloc.free over the vl family: grow (pool
    pops, terminate + link the new segments after the tail), per-lane
    value writes, tail update."""
    cls, valid = _lane_prep(cfg, sizes, valid_i32, offsets)
    n = cls.shape[0]
    spc = cfg.slots_per_segment("vl")
    wpc = cfg.words_per_chunk
    W = cfg.total_words
    m = n // spc + 1

    member, rank, cnt = _member_rank(cls, valid, c)
    back = _ld(octl, lay.off_back + c)
    member_i = member.astype(jnp.int32)
    heap_ref = E["heap"]
    tail = _ld(octl, lay.off_tail + c)

    new_chunks, new_tail = _vl_grow(octl, E["pool_store"], heap_ref,
                                    lay, spc, wpc, W, tail, back, cnt,
                                    m)
    seg_vec = jnp.stack([tail] + new_chunks)

    def lane(i, _):
        g = _take(member_i, i) != 0
        v = back + _take(rank, i)
        seg = _take(seg_vec, v // spc - back // spc)
        word = seg * wpc + 1 + v % spc
        _st_if(heap_ref, word, _take(offsets, i),
               g & (word >= 0) & (word < W))
        return 0

    jax.lax.fori_loop(0, n, lane, 0)

    _st(octl, lay.off_tail + c, new_tail)
    _st(octl, lay.off_back + c, back + cnt)


# --------------------------------------------------------------------------
# chunk-kind bodies: the per-class chunk-drain loop
# --------------------------------------------------------------------------

def _bitmap_claim(row_u, ppc, t, maxbits, bw):
    """Rank-select + claim over one staged bitmap row (the in-kernel
    form of chunk_alloc._select_free_pages + _set_bits(+1), mirroring
    kernels/alloc_txn._claim_kernel).  Returns (page_idx, new_row_u,
    total): the first ``t`` free page indices ascending (-1 padded),
    the row with those bits set, and the claimed count."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, 32), 1)
    occ = ((row_u[:, None] >> shifts) & 1).astype(jnp.int32)
    occ = occ.reshape(maxbits)
    p = _iota(maxbits)
    free = (occ == 0) & (p < ppc)
    fi = free.astype(jnp.int32)
    order = jnp.cumsum(fi) - fi
    chosen = free & (order < t)
    total = jnp.sum(chosen.astype(jnp.int32))
    onehot = chosen[None, :] & (order[None, :] == p[:, None])
    pidx = jnp.sum(jnp.where(onehot, p[None, :], 0), axis=1)
    page_idx = jnp.where(p < total, pidx, -1)
    add = jnp.sum(jnp.where(chosen.reshape(bw, 32),
                            jnp.uint32(1) << shifts, jnp.uint32(0)),
                  axis=1)
    return page_idx, row_u + add, total


def _chunk_alloc(cfg, lay, family, c, sizes, valid_i32, E, octl,
                 offs_ref):
    """Class-c slice of chunk_alloc.alloc: the dynamic chunk-drain loop
    — pop a chunk from the class queue (or claim a fresh one from the
    pool), stage its bitmap row from HBM, rank-select + claim pages,
    scatter granted offsets to the requesting lanes, re-enqueue the
    chunk while pages remain.  Queue traffic goes through the staged
    class row (ring), the directory row (va), or the head/tail chain
    in ctl + heap (vl), exactly one item at a time, as in the oracle's
    while loop."""
    C = cfg.num_classes
    nc = cfg.num_chunks
    wpc = cfg.words_per_chunk
    bw = cfg.bitmap_words_per_chunk
    maxbits = bw * 32
    W = cfg.total_words
    pw0 = cfg.page_words(0)
    spc = cfg.slots_per_segment(family)
    max_segs = lay.max_segs

    cls, valid = _lane_prep(cfg, sizes, valid_i32)
    n = cls.shape[0]
    member, rank, _ = _member_rank(cls, valid, c)
    counts_c = jnp.sum(member.astype(jnp.int32))
    pw = pw0 << c                       # page words of class c (traced)
    ppc = cfg.words_per_chunk // pw     # pages per chunk of class c

    bitmap_ref = E["bitmap"]
    fc_ref = E["free_count"]
    cc_ref = E["chunk_class"]
    pool_ref = E["pool_store"]
    heap_ref = E.get("heap")
    qrow = E.get("queue_store")
    dir_ref = E.get("directory")

    def body(carry):
        served, fail = carry
        front = _ld(octl, lay.off_front + c)
        back = _ld(octl, lay.off_back + c)
        have = (back - front) > 0

        # -- pop one chunk from the class queue (family-specific) ------
        if family == "ring":
            cap = qrow.shape[1]
            val_q = _row_ld(qrow, front % cap)
            _st(octl, lay.off_front + c, front + jnp.where(have, 1, 0))
        elif family == "va":
            seg = _row_ld(dir_ref, (front // spc) % max_segs)
            word = seg * wpc + front % spc
            val_q = _ld_if(heap_ref, word, have & (word >= 0) & (word < W))
            crossed = (front + 1) // spc - front // spc > 0
            _pool_push1(octl, pool_ref, lay, seg, have & crossed)
            _st(octl, lay.off_front + c, front + jnp.where(have, 1, 0))
        else:  # vl
            head = _ld(octl, lay.off_head + c)
            word = head * wpc + 1 + front % spc
            val_q = _ld_if(heap_ref, word, have & (word >= 0) & (word < W))
            nxt = _ld_if(heap_ref, head * wpc, head >= 0)
            crossed = (front + 1) // spc - front // spc > 0
            sh = have & crossed
            _pool_push1(octl, pool_ref, lay, head, sh)
            _st(octl, lay.off_head + c, jnp.where(sh, nxt, head))
            _st(octl, lay.off_front + c, front + jnp.where(have, 1, 0))

        # -- else claim a fresh chunk from the pool --------------------
        pf = _ld(octl, lay.off_pool_front)
        pb = _ld(octl, lay.off_pool_back)
        has = (pb - pf) > 0
        take_pool = (~have) & has
        ch_p = _ld_if(pool_ref, pf % nc, take_pool)
        _st(octl, lay.off_pool_front, pf + jnp.where(take_pool, 1, 0))
        fail_now = (~have) & (~has)
        chunk = jnp.where(have, val_q, jnp.where(has, ch_p, NULL))
        # fresh chunk: zero bitmap row, full free count, bind to class c
        safe_p = jnp.clip(jnp.where(ch_p < 0, ch_p + nc, ch_p), 0, nc - 1)
        _vec_st_if(bitmap_ref, safe_p * bw, jnp.zeros(bw, jnp.int32),
                   take_pool)
        _st_if(fc_ref, safe_p, ppc, take_pool)
        _st_if(cc_ref, safe_p, c, take_pool)

        # -- stage the chunk's bitmap row, rank-select + claim ---------
        # (index normalization mirrors jnp's negative-wrap + clamp
        # gather semantics on bitmap[chunk] for the chunk = -1 case,
        # where t == 0 makes the claim a no-op on whatever row)
        idxc = jnp.clip(jnp.where(chunk < 0, chunk + nc, chunk), 0, nc - 1)
        f = jnp.where(fail_now, 0, _ld(fc_ref, idxc))
        t = jnp.minimum(counts_c - served, f)
        row_u = jax.lax.bitcast_convert_type(
            _vec_ld(bitmap_ref, idxc * bw, bw), jnp.uint32)
        page_idx, new_row_u, total = _bitmap_claim(row_u, ppc, t,
                                                   maxbits, bw)
        _vec_st(bitmap_ref, idxc * bw,
                jax.lax.bitcast_convert_type(new_row_u, jnp.int32))
        _st_if(fc_ref, idxc, f - total, total > 0)

        # -- scatter granted offsets to the lanes of this iteration ----
        lane_sel = member & (rank >= served) & (rank < served + total)
        pidx_lane = _gather_small(page_idx, rank - served)
        offs_ref[...] = jnp.where(lane_sel, chunk * wpc + pidx_lane * pw,
                                  offs_ref[...])

        # -- chunk still has pages -> back into the class queue --------
        leftover = (~fail_now) & (f - total > 0)
        back = _ld(octl, lay.off_back + c)
        if family == "ring":
            cap = qrow.shape[1]
            _row_st_if(qrow, back % cap, chunk, leftover)
            _st(octl, lay.off_back + c,
                back + jnp.where(leftover, 1, 0))
        elif family == "va":
            lv = jnp.where(leftover, 1, 0)
            _va_grow(octl, pool_ref, dir_ref, lay, spc, back, lv, 1)
            seg = _row_ld(dir_ref, (back // spc) % max_segs)
            word = seg * wpc + back % spc
            _st_if(heap_ref, word, chunk,
                   leftover & (word >= 0) & (word < W))
            _st(octl, lay.off_back + c, back + lv)
        else:  # vl
            tail = _ld(octl, lay.off_tail + c)
            lv = jnp.where(leftover, 1, 0)
            _, new_tail = _vl_grow(octl, pool_ref, heap_ref, lay, spc,
                                   wpc, W, tail, back, lv, 1)
            word = tail * wpc + 1 + back % spc
            _st_if(heap_ref, word, chunk,
                   leftover & (word >= 0) & (word < W))
            _st(octl, lay.off_tail + c, new_tail)
            _st(octl, lay.off_back + c, back + lv)

        return served + t, fail | fail_now

    jax.lax.while_loop(
        lambda cr: (cr[0] < counts_c) & ~cr[1],
        body, (jnp.int32(0), jnp.asarray(False)))


def _chunk_free(cfg, lay, family, c, offsets, sizes, valid_i32, E, octl,
                aux_ref, old_free_ref):
    """Chunk-kind free.  Step 0 clears the freed page bits (one staged
    bitmap-row RMW per lane), bumps free counts, and records the
    full->non-full transitions in ``aux``; every step then re-enqueues
    its own class's revived chunks (ascending chunk id, the oracle's
    nonzero order) through the class row / directory / chain."""
    C = cfg.num_classes
    nc = cfg.num_chunks
    wpc = cfg.words_per_chunk
    bw = cfg.bitmap_words_per_chunk
    W = cfg.total_words
    pw0 = cfg.page_words(0)
    spc = cfg.slots_per_segment(family)
    max_segs = lay.max_segs

    cls, valid = _lane_prep(cfg, sizes, valid_i32, offsets)
    n = cls.shape[0]
    m = n // spc + 1

    bitmap_ref = E["bitmap"]
    fc_ref = E["free_count"]
    pool_ref = E.get("pool_store")
    heap_ref = E.get("heap")
    qrow = E.get("queue_store")
    dir_ref = E.get("directory")

    chunk_v = offsets // wpc
    pw_v = pw0 << (cls % C)
    page_v = (offsets % wpc) // pw_v
    ok_v = valid & (chunk_v >= 0) & (chunk_v < nc)
    ok_i = ok_v.astype(jnp.int32)
    word_v = chunk_v * bw + page_v // 32
    bit_v = (page_v % 32).astype(jnp.uint32)

    @pl.when(c == 0)
    def _clear():
        # full -> non-full transitions, against the PRE-clear counts
        iota_nc = jax.lax.broadcasted_iota(jnp.int32, (n, nc), 1)
        touched = jnp.any((chunk_v[:, None] == iota_nc) & ok_v[:, None],
                          axis=0)
        revived = touched & (old_free_ref[...] == 0)
        aux_ref[...] = revived.astype(jnp.int32)

        def lane(i, _):
            ok = _take(ok_i, i) != 0
            a = _take(word_v, i)
            old_u = jax.lax.bitcast_convert_type(
                jnp.reshape(_ld_if(bitmap_ref, a, ok, 0), (1,)),
                jnp.uint32)
            bitval = jnp.uint32(1) << _take(bit_v, i)
            new = jax.lax.bitcast_convert_type(old_u - bitval,
                                               jnp.int32)[0]
            _st_if(bitmap_ref, a, new, ok)
            ch = _take(chunk_v, i)
            cur = _ld_if(fc_ref, ch, ok, 0)
            _st_if(fc_ref, ch, cur + 1, ok)
            return 0

        jax.lax.fori_loop(0, n, lane, 0)

    # -- re-enqueue this class's revived chunks ------------------------
    rev = aux_ref[...] != 0
    active = rev & (E["chunk_class"][...] == c)
    ai = active.astype(jnp.int32)
    rank_v = jnp.cumsum(ai) - ai
    cnt = jnp.sum(ai)
    back = _ld(octl, lay.off_back + c)

    if family == "ring":
        cap = qrow.shape[1]

        def put(k, _):
            _row_st_if(qrow, (back + _take(rank_v, k)) % cap, k,
                       _take(ai, k) != 0)
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
    elif family == "va":
        _va_grow(octl, pool_ref, dir_ref, lay, spc, back, cnt, m)

        def put(k, _):
            v = back + _take(rank_v, k)
            seg = _row_ld(dir_ref, (v // spc) % max_segs)
            word = seg * wpc + v % spc
            _st_if(heap_ref, word, k,
                   (_take(ai, k) != 0) & (word >= 0) & (word < W))
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
    else:  # vl
        tail = _ld(octl, lay.off_tail + c)
        new_chunks, new_tail = _vl_grow(octl, pool_ref, heap_ref, lay,
                                        spc, wpc, W, tail, back, cnt, m)
        seg_vec = jnp.stack([tail] + new_chunks)

        def put(k, _):
            v = back + _take(rank_v, k)
            seg = _take(seg_vec, v // spc - back // spc)
            word = seg * wpc + 1 + v % spc
            _st_if(heap_ref, word, k,
                   (_take(ai, k) != 0) & (word >= 0) & (word < W))
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
        _st(octl, lay.off_tail + c, new_tail)

    _st(octl, lay.off_back + c, back + cnt)


# --------------------------------------------------------------------------
# ctl telemetry accumulators (DESIGN.md §14; obs/telemetry.py is the
# bit-exact oracle)
# --------------------------------------------------------------------------
#
# Every telemetry word is a pure function of observable transaction
# state — lane inputs, granted offsets, core-counter before/after
# values — so the scalar per-class updates below provably equal the
# oracle's vectorized whole-transaction deltas: step c touches exactly
# class c's front/back, and the shared pool counters telescope across
# the sequential class steps (each step's post is the next step's pre).

def _tele_bump(octl, addr, delta):
    _st(octl, addr, _ld(octl, addr) + delta)


def _tele_scalars(octl, lay, c):
    """(front[c], back[c], pool_front, pool_back) — the core counters a
    class step can move, sampled around the per-class body."""
    return (_ld(octl, lay.off_front + c), _ld(octl, lay.off_back + c),
            _ld(octl, lay.off_pool_front), _ld(octl, lay.off_pool_back))


def _tele_counters(lay, octl, c, pre, post):
    """Wrap/grow/shrink/pool-wrap deltas of one class step."""
    f0, b0, pf0, pb0 = pre
    f1, b1, pf1, pb1 = post
    capw = lay.wrap_capacity
    nc = lay.cfg.num_chunks
    _tele_bump(octl, lay.off_t_wrap + c,
               (f1 // capw - f0 // capw) + (b1 // capw - b0 // capw))
    _tele_bump(octl, lay.off_t_grow, pf1 - pf0)
    _tele_bump(octl, lay.off_t_shrink, pb1 - pb0)
    _tele_bump(octl, lay.off_t_pool_wrap,
               (pf1 // nc - pf0 // nc) + (pb1 // nc - pb0 // nc))


def _tele_alloc(cfg, lay, octl, c, sizes, valid_i32, cur, new, attempt):
    """Per-class alloc/failure counts + walk-depth histogram from the
    step's lane transitions (``cur``/``new`` are the offsets vector
    before/after the body, shard-local under sharding)."""
    cls = size_to_class_device(cfg, sizes)
    member = (valid_i32 != 0) & (cls == c)
    served = jnp.sum((member & (cur < 0) & (new >= 0))
                     .astype(jnp.int32))
    failed = jnp.sum((member & (new < 0)).astype(jnp.int32))
    _tele_bump(octl, lay.off_t_alloc + c, served)
    _tele_bump(octl, lay.off_t_fail + c, failed)
    nbin = jnp.minimum(jnp.asarray(attempt, jnp.int32),
                       arena.TELE_WALK_BINS - 1)
    _tele_bump(octl, lay.off_t_walk + nbin, served)


def _tele_free(cfg, lay, octl, c, offsets, sizes, valid_i32):
    """Per-class free counts — a pure function of the lane inputs."""
    cls = size_to_class_device(cfg, sizes)
    freed = (valid_i32 != 0) & (cls == c) & (offsets >= 0)
    _tele_bump(octl, lay.off_t_free + c,
               jnp.sum(freed.astype(jnp.int32)))


# --------------------------------------------------------------------------
# wrapper: per-region specs from the ArenaLayout, one pallas_call
# --------------------------------------------------------------------------
#
# Region sets per transaction: `reads` enter the kernel, `writes` come
# back out (everything else bypasses it — arena.split/join are static
# slices).  A region in both with blocking "hbm" is input/output-
# aliased, so on device the transaction updates it in place.

_READS = {
    ("page", "ring", "alloc"): ("queue_store",),
    ("page", "ring", "free"): ("queue_store",),
    ("page", "va", "alloc"): ("heap", "pool_store", "directory"),
    ("page", "va", "free"): ("heap", "pool_store", "directory"),
    ("page", "vl", "alloc"): ("heap", "pool_store"),
    ("page", "vl", "free"): ("heap", "pool_store"),
    ("chunk", "ring", "alloc"): ("pool_store", "queue_store", "bitmap",
                                 "free_count", "chunk_class"),
    ("chunk", "ring", "free"): ("queue_store", "bitmap", "free_count",
                                "chunk_class"),
    ("chunk", "va", "alloc"): ("heap", "pool_store", "directory",
                               "bitmap", "free_count", "chunk_class"),
    ("chunk", "va", "free"): ("heap", "pool_store", "directory",
                              "bitmap", "free_count", "chunk_class"),
    ("chunk", "vl", "alloc"): ("heap", "pool_store", "bitmap",
                               "free_count", "chunk_class"),
    ("chunk", "vl", "free"): ("heap", "pool_store", "bitmap",
                              "free_count", "chunk_class"),
}

_WRITES = {
    ("page", "ring", "alloc"): (),
    ("page", "ring", "free"): ("queue_store",),
    ("page", "va", "alloc"): ("pool_store",),
    ("page", "va", "free"): ("heap", "directory"),
    ("page", "vl", "alloc"): ("pool_store",),
    ("page", "vl", "free"): ("heap",),
    ("chunk", "ring", "alloc"): ("queue_store", "bitmap", "free_count",
                                 "chunk_class"),
    ("chunk", "ring", "free"): ("queue_store", "bitmap", "free_count"),
    ("chunk", "va", "alloc"): ("heap", "pool_store", "directory",
                               "bitmap", "free_count", "chunk_class"),
    ("chunk", "va", "free"): ("heap", "directory", "bitmap",
                              "free_count"),
    ("chunk", "vl", "alloc"): ("heap", "pool_store", "bitmap",
                               "free_count", "chunk_class"),
    ("chunk", "vl", "free"): ("heap", "bitmap", "free_count"),
}


def _region_arr(lay, parts, name):
    r = lay.region(name)
    return (parts[name].reshape(r.shape) if r.blocking == "row"
            else parts[name])


def _region_spec(lay, name):
    r = lay.region(name)
    if r.blocking == "row":
        return pl.BlockSpec((1,) + r.shape[1:], lambda c, s: (c, 0))
    if r.blocking == "resident":
        return pl.BlockSpec((r.words,), lambda c, s: (0,))
    return pl.BlockSpec(memory_space=pltpu.ANY)


def _region_shape(lay, name):
    r = lay.region(name)
    shape = r.shape if r.blocking == "row" else (r.words,)
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _txn_call(cfg, kind, family, op, mem, ctl, lanes, n, interpret):
    lay = arena.layout(cfg, kind, family)
    parts = arena.split(lay, mem)
    reads = _READS[(kind, family, op)]
    writes = _WRITES[(kind, family, op)]
    C = cfg.num_classes

    in_arrays = list(lanes) + [_region_arr(lay, parts, nm)
                               for nm in reads]
    in_specs = ([pl.BlockSpec((n,), lambda c, s: (0,))] * len(lanes)
                + [_region_spec(lay, nm) for nm in reads])

    out_specs = [_region_spec(lay, nm) for nm in writes]
    out_shapes = [_region_shape(lay, nm) for nm in writes]
    out_specs.append(pl.BlockSpec((lay.ctl_words,), lambda c, s: (0,)))
    out_shapes.append(jax.ShapeDtypeStruct((lay.ctl_words,), jnp.int32))
    if op == "alloc":
        out_specs.append(pl.BlockSpec((n,), lambda c, s: (0,)))
        out_shapes.append(jax.ShapeDtypeStruct((n,), jnp.int32))
    elif kind == "chunk":
        # revived-chunk flags, computed at step 0 and read by every
        # class step (grid-persistent VMEM block)
        out_specs.append(pl.BlockSpec((cfg.num_chunks,),
                                      lambda c, s: (0,)))
        out_shapes.append(jax.ShapeDtypeStruct((cfg.num_chunks,),
                                               jnp.int32))

    aliases = {1 + len(lanes) + reads.index(nm): writes.index(nm)
               for nm in writes if lay.region(nm).blocking == "hbm"}

    n_in = len(in_arrays)
    n_w = len(writes)

    def kernel(ctl_ref, *refs):
        in_refs, out_refs = refs[:n_in], refs[n_in:]
        lane_vals = [r[...] for r in in_refs[:len(lanes)]]
        R = dict(zip(reads, in_refs[len(lanes):]))
        O = dict(zip(writes, out_refs[:n_w]))
        octl = out_refs[n_w]
        c = pl.program_id(0)

        @pl.when(c == 0)
        def _init():
            octl[...] = ctl_ref[...]
            for nm in writes:
                blocking = lay.region(nm).blocking
                if blocking == "resident":
                    O[nm][...] = R[nm][...]
                elif blocking == "hbm" and interpret:
                    # hbm write regions are input/output-aliased: on
                    # device in == out and this copy would be a no-op
                    # O(region) DMA, so it exists only for interpret
                    # mode, whose output buffers start unaliased.
                    O[nm][...] = R[nm][...]
            if op == "alloc":
                out_refs[n_w + 1][...] = jnp.full((n,), NULL, jnp.int32)

        for nm in writes:          # stage this class's row through VMEM
            if lay.region(nm).blocking == "row":
                O[nm][0, :] = R[nm][0, :]
        E = {nm: O.get(nm, R[nm]) for nm in reads}

        pre = _tele_scalars(octl, lay, c)
        if op == "alloc":
            offs_ref = out_refs[n_w + 1]
            cur = offs_ref[...]
            if kind == "page":
                fn = {"ring": _page_ring_alloc, "va": _page_va_alloc,
                      "vl": _page_vl_alloc}[family]
                fn(cfg, lay, c, lane_vals[0], lane_vals[1], E, octl,
                   offs_ref)
            else:
                _chunk_alloc(cfg, lay, family, c, lane_vals[0],
                             lane_vals[1], E, octl, offs_ref)
            _tele_counters(lay, octl, c, pre, _tele_scalars(octl, lay, c))
            _tele_alloc(cfg, lay, octl, c, lane_vals[0], lane_vals[1],
                        cur, offs_ref[...], 0)
        else:
            offsets, sizes, valid = lane_vals
            if kind == "page":
                fn = {"ring": _page_ring_free, "va": _page_va_free,
                      "vl": _page_vl_free}[family]
                fn(cfg, lay, c, offsets, sizes, valid, E, octl)
            else:
                _chunk_free(cfg, lay, family, c, offsets, sizes, valid,
                            E, octl, out_refs[n_w + 1],
                            R["free_count"])
            _tele_counters(lay, octl, c, pre, _tele_scalars(octl, lay, c))
            _tele_free(cfg, lay, octl, c, offsets, sizes, valid)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(C,),
        in_specs=in_specs, out_specs=out_specs)
    outs = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        input_output_aliases=aliases, interpret=interpret,
    )(ctl.astype(jnp.int32), *in_arrays)

    new_parts = dict(parts)
    for nm, val in zip(writes, outs[:n_w]):
        new_parts[nm] = val
    return arena.join(lay, new_parts), outs[n_w], outs[n_w + 1:]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_alloc_txn_blocked(cfg, kind, family, mem, ctl, sizes_bytes,
                            mask, *, interpret: bool = False):
    """Region-blocked whole-transaction alloc: ONE ``pallas_call``,
    bit-identical to ``transactions.alloc_math`` and to the whole-arena
    lowering.  Returns ``(new_mem, new_ctl, offsets)``."""
    n = sizes_bytes.shape[0]
    lanes = (sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))
    mem2, octl, extra = _txn_call(cfg, kind, family, "alloc", mem, ctl,
                                  lanes, n, interpret)
    return mem2, octl, extra[0]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_free_txn_blocked(cfg, kind, family, mem, ctl, offsets_words,
                           sizes_bytes, mask, *, interpret: bool = False):
    """Region-blocked whole-transaction free.  Returns
    ``(new_mem, new_ctl)``."""
    n = sizes_bytes.shape[0]
    lanes = (offsets_words.astype(jnp.int32),
             sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))
    mem2, octl, _ = _txn_call(cfg, kind, family, "free", mem, ctl,
                              lanes, n, interpret)
    return mem2, octl


# --------------------------------------------------------------------------
# sharded wrapper: the (attempt, shard, class) grid (DESIGN.md §9)
# --------------------------------------------------------------------------
#
# The sharded blocked lowering reuses every per-class body above
# untouched: the grid grows two leading dimensions — attempt a (the
# overflow walk; 1 for free) and shard s — and every region spec gains
# a shard coordinate:
#
# - row regions stack to (S·C, w) and step (a, s, c) stages row
#   s·C + c — still exactly one class row in VMEM per step;
# - resident regions stack flat to (S·words,) with a (words,) block
#   selected by s, so the bodies keep seeing a single shard's block;
# - hbm regions stay ONE flat (S·words,) ANY ref; the bodies receive a
#   _ShardView(ref, s·words), so every word address they compute lands
#   in shard s's slice;
# - ctl prefetches flat (S·ctl_words,); the accumulator output is
#   blocked per shard the same way.
#
# Output blocks are staged from the inputs on each (shard, row)'s
# FIRST visit only (a == 0): later attempts revisit the block and must
# see the earlier attempts' updates, not the boundary state.  The
# per-class bodies return shard-LOCAL offsets; the wrapper globalizes
# newly-served lanes (prev < 0, new >= 0) with s · shard_words before
# the next grid step, which is also what keeps the "still unserved"
# test (offs < 0) correct across attempts.

def _txn_call_sharded(cfg, num_shards, walk, kind, family, op, mem, ctl,
                      lanes, n, interpret):
    from repro.core import shards as _shards  # lazy: kernels <-> core

    S = num_shards
    scfg = _shards.shard_config(cfg, S)
    slay = _shards.layout(cfg, S, kind, family)
    lay = slay.shard
    Ws = scfg.total_words
    C = scfg.num_classes
    Cw = lay.ctl_words
    parts = _shards.split_regions(slay, mem)   # {name: (S, words)}
    reads = _READS[(kind, family, op)]
    writes = _WRITES[(kind, family, op)]
    A = walk + 1 if op == "alloc" else 1
    hbm_words = {nm: lay.region(nm).words
                 for nm in set(reads) | set(writes)
                 if lay.region(nm).blocking == "hbm"}

    def _arr(name):
        r = lay.region(name)
        p = parts[name]
        if r.blocking == "row":
            return p.reshape(S * r.shape[0], r.shape[1])
        return p.reshape(S * r.words)

    def _spec(name):
        r = lay.region(name)
        if r.blocking == "row":
            return pl.BlockSpec((1, r.shape[1]),
                                lambda a, s, c, t, C=C: (s * C + c, 0))
        if r.blocking == "resident":
            return pl.BlockSpec((r.words,), lambda a, s, c, t: (s,))
        return pl.BlockSpec(memory_space=pltpu.ANY)

    def _oshape(name):
        r = lay.region(name)
        if r.blocking == "row":
            return jax.ShapeDtypeStruct((S * r.shape[0], r.shape[1]),
                                        jnp.int32)
        return jax.ShapeDtypeStruct((S * r.words,), jnp.int32)

    lane_spec = pl.BlockSpec((n,), lambda a, s, c, t: (0,))
    in_arrays = list(lanes) + [_arr(nm) for nm in reads]
    in_specs = [lane_spec] * len(lanes) + [_spec(nm) for nm in reads]

    out_specs = [_spec(nm) for nm in writes]
    out_shapes = [_oshape(nm) for nm in writes]
    out_specs.append(pl.BlockSpec((Cw,), lambda a, s, c, t: (s,)))
    out_shapes.append(jax.ShapeDtypeStruct((S * Cw,), jnp.int32))
    if op == "alloc":
        out_specs.append(pl.BlockSpec((n,), lambda a, s, c, t: (0,)))
        out_shapes.append(jax.ShapeDtypeStruct((n,), jnp.int32))
    elif kind == "chunk":
        # revived-chunk flags, per shard (computed at the shard's
        # c == 0 step, read by its every class step)
        out_specs.append(pl.BlockSpec((scfg.num_chunks,),
                                      lambda a, s, c, t: (s,)))
        out_shapes.append(jax.ShapeDtypeStruct((S * scfg.num_chunks,),
                                               jnp.int32))

    aliases = {1 + len(lanes) + reads.index(nm): writes.index(nm)
               for nm in writes if lay.region(nm).blocking == "hbm"}

    n_in = len(in_arrays)
    n_w = len(writes)

    def kernel(ctl_ref, *refs):
        in_refs, out_refs = refs[:n_in], refs[n_in:]
        lane_vals = [r[...] for r in in_refs[:len(lanes)]]
        R = dict(zip(reads, in_refs[len(lanes):]))
        O = dict(zip(writes, out_refs[:n_w]))
        octl = out_refs[n_w]
        a = pl.program_id(0)
        s = pl.program_id(1)
        c = pl.program_id(2)

        @pl.when((a == 0) & (s == 0) & (c == 0))
        def _once():
            if interpret:
                # hbm write regions are input/output-aliased: on device
                # this copy is a no-op; interpret-mode output buffers
                # start unaliased (as in _txn_call).
                for nm in writes:
                    if lay.region(nm).blocking == "hbm":
                        O[nm][...] = R[nm][...]
            if op == "alloc":
                out_refs[n_w + 1][...] = jnp.full((n,), NULL, jnp.int32)

        @pl.when((a == 0) & (c == 0))
        def _per_shard():
            octl[...] = pl.load(ctl_ref, (pl.ds(s * Cw, Cw),))
            for nm in writes:
                if lay.region(nm).blocking == "resident":
                    O[nm][...] = R[nm][...]

        @pl.when(a == 0)
        def _stage_rows():   # each (s, c) row's first (only input) copy
            for nm in writes:
                if lay.region(nm).blocking == "row":
                    O[nm][0, :] = R[nm][0, :]

        def _wrap(nm, ref):
            if lay.region(nm).blocking == "hbm":
                return _ShardView(ref, s * hbm_words[nm])
            return ref

        E = {nm: _wrap(nm, O.get(nm, R[nm])) for nm in reads}

        pre = _tele_scalars(octl, lay, c)
        if op == "alloc":
            sizes, valid, home = lane_vals
            offs_ref = out_refs[n_w + 1]
            cur = offs_ref[...]
            sel = ((valid != 0) & ((home + a) % S == s) & (cur < 0))
            sel_i = sel.astype(jnp.int32)
            if kind == "page":
                fn = {"ring": _page_ring_alloc, "va": _page_va_alloc,
                      "vl": _page_vl_alloc}[family]
                fn(scfg, lay, c, sizes, sel_i, E, octl, offs_ref)
            else:
                _chunk_alloc(scfg, lay, family, c, sizes, sel_i, E,
                             octl, offs_ref)
            new = offs_ref[...]
            _tele_counters(lay, octl, c, pre, _tele_scalars(octl, lay, c))
            # counts from the shard-LOCAL offsets, mask = this visit's
            # selection — matches the oracle's per-(attempt, shard)
            # alloc_math telemetry attribution
            _tele_alloc(scfg, lay, octl, c, sizes, sel_i, cur, new, a)
            offs_ref[...] = jnp.where((cur < 0) & (new >= 0),
                                      new + s * Ws, new)
        else:
            offsets, sizes, valid = lane_vals
            sh = jnp.where(offsets >= 0, offsets // Ws, -1)
            sel = (valid != 0) & (sh == s)
            local = jnp.where(sel, offsets - s * Ws, -1)
            sel_i = sel.astype(jnp.int32)
            if kind == "page":
                fn = {"ring": _page_ring_free, "va": _page_va_free,
                      "vl": _page_vl_free}[family]
                fn(scfg, lay, c, local, sizes, sel_i, E, octl)
            else:
                _chunk_free(scfg, lay, family, c, local, sizes, sel_i,
                            E, octl, out_refs[n_w + 1],
                            R["free_count"])
            _tele_counters(lay, octl, c, pre, _tele_scalars(octl, lay, c))
            _tele_free(scfg, lay, octl, c, local, sizes, sel_i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(A, S, C),
        in_specs=in_specs, out_specs=out_specs)
    outs = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        input_output_aliases=aliases, interpret=interpret,
    )(ctl.reshape(-1).astype(jnp.int32), *in_arrays)

    new_parts = dict(parts)
    for nm, val in zip(writes, outs[:n_w]):
        new_parts[nm] = val.reshape(S, -1)
    new_mem = _shards.join_regions(slay, new_parts)
    new_ctl = outs[n_w].reshape(S, Cw)
    return new_mem, new_ctl, outs[n_w + 1:]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "walk", "interpret"))
def sharded_arena_alloc_txn_blocked(cfg, num_shards, kind, family, mem,
                                    ctl, sizes_bytes, mask, home, walk,
                                    *, interpret: bool = False):
    """Sharded region-blocked alloc: ONE ``pallas_call`` over the
    (attempt, shard, class) grid, bit-identical to
    ``transactions.sharded_alloc_math`` and to the sharded whole
    lowering.  Returns ``(new_mem, new_ctl, global_offsets)``."""
    n = sizes_bytes.shape[0]
    lanes = (sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32),
             home.astype(jnp.int32))
    mem2, octl, extra = _txn_call_sharded(cfg, num_shards, walk, kind,
                                          family, "alloc", mem, ctl,
                                          lanes, n, interpret)
    return mem2, octl, extra[0]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "interpret"))
def sharded_arena_free_txn_blocked(cfg, num_shards, kind, family, mem,
                                   ctl, offsets_words, sizes_bytes,
                                   mask, *, interpret: bool = False):
    """Sharded region-blocked free: grid (1, shard, class).  Returns
    ``(new_mem, new_ctl)``."""
    n = sizes_bytes.shape[0]
    lanes = (offsets_words.astype(jnp.int32),
             sizes_bytes.astype(jnp.int32), mask.astype(jnp.int32))
    mem2, octl, _ = _txn_call_sharded(cfg, num_shards, 0, kind, family,
                                      "free", mem, ctl, lanes, n,
                                      interpret)
    return mem2, octl
