"""Pallas kernels: one fused migration wave per ``pallas_call``.

The defragmentation execute step (core/defrag.py, DESIGN.md §10) —
copy each planned extent's heap words, flip its occupancy bits, move
the free counts, retire emptied chunks to the pool, and rebuild the
class queues — runs as ONE ``pallas_call`` per wave under both kernel
lowerings, exactly like the alloc/free transactions:

``arena_defrag_txn`` (whole lowering)
    the kernel body IS ``defrag.migrate_math`` over full ``mem``/``ctl``
    refs (parity with the jnp oracle is structural, as in
    kernels/alloc_txn.arena_*_txn); ``mem``/``ctl`` are input/output-
    aliased so the wave rewrites the arena in place.

``arena_defrag_txn_blocked`` (region-blocked lowering)
    the §8 discipline applied to a wave: grid over the size classes,
    control block as scalar prefetch accumulated in a resident VMEM
    block, pool/free-count/binding regions resident, queue ring or
    directory rows staged per class step, heap and bitmaps as HBM(ANY)
    refs touched word-by-word through the alloc_txn_blocked DMA
    vocabulary.  Step 0 runs the migration (extract every source
    extent into a carry buffer, insert at the destinations — windowed
    row loads/stores, bit RMWs) plus the unbind/pool re-prime; every
    step ``c`` then rebuilds class ``c``'s queue in the oracle's
    class-major order.  NOTE: defrag writes regions that alloc/free
    never touch (the chunk-ring heap, for one), so the region
    treatment here is defrag's own table, not ``Region.blocking``.

``sharded_arena_defrag_txn`` / ``sharded_arena_defrag_txn_blocked``
    the (phase, shard) schedule of ``defrag.sharded_migrate_math`` as
    one grid — phase 0 extracts every source shard's extents into the
    carry buffer, phase 1 inserts and rebuilds every shard — so a
    single wave covers in-shard compaction AND cross-shard rebalance
    moves.  The whole lowering grids (2, S) over shard slabs; the
    blocked lowering grids (2, S, C) with the §9 region stacking
    (rows at ``s·C + c``, resident blocks per shard, hbm regions as
    flat ``(S·words,)`` ANY refs through ``_ShardView``).

The plan (``src``/``dst``/``sizes`` forwarding table) is computed once
in pure jnp and shared by every backend; the execute contract assumes
what the planners guarantee — source extents and destination slots are
disjoint — so extract-then-insert equals a simultaneous move.
tests/test_defrag.py holds every implementation word-identical to the
oracle and asserts the one-kernel property on the jaxpr.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import arena
from repro.core.heap import size_to_class_device
from repro.kernels.alloc_txn import _iota
from repro.kernels.alloc_txn_blocked import (NULL, _ShardView, _ld_if,
                                             _pool_pop1, _row_ld,
                                             _row_st_if, _st, _st_if,
                                             _take, _va_grow, _vec_ld,
                                             _vec_st_if, _vl_grow)


# --------------------------------------------------------------------------
# whole lowering: the kernel body is the oracle itself
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_defrag_txn(cfg, kind, family, mem, ctl, src, dst, sizes, *,
                     interpret: bool = False):
    """One whole migration wave as ONE pallas_call (whole lowering).
    Returns ``(new_mem, new_ctl)`` — bit-identical to
    ``defrag.migrate_math``, which is also the kernel body."""
    from repro.core import defrag  # lazy: kernels <-> core

    def kernel(mem_ref, ctl_ref, src_ref, dst_ref, sizes_ref,
               omem_ref, octl_ref):
        nm, nc2 = defrag.migrate_math(
            cfg, kind, family, mem_ref[...], ctl_ref[...], src_ref[...],
            dst_ref[...], sizes_ref[...])
        omem_ref[...] = nm
        octl_ref[...] = nc2

    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(mem.shape, jnp.int32),
                   jax.ShapeDtypeStruct(ctl.shape, jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, src.astype(jnp.int32), dst.astype(jnp.int32),
      sizes.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "interpret"))
def sharded_arena_defrag_txn(cfg, num_shards, kind, family, mem, ctl,
                             src, dst, sizes, *,
                             interpret: bool = False):
    """Sharded wave: ONE pallas_call gridding the (phase, shard)
    schedule — phase 0 extracts each source shard into the carry
    buffer, phase 1 inserts + rebuilds each shard.  Bit-identical to
    ``defrag.sharded_migrate_math`` (the kernel body reuses its
    extract/insert math per shard row)."""
    from repro.core import defrag, shards  # lazy: kernels <-> core

    S = num_shards
    scfg = shards.shard_config(cfg, S)
    Ws = scfg.total_words
    Mw, Cw = mem.shape[1], ctl.shape[1]
    M = src.shape[0]
    maxw = scfg.words_per_chunk

    def kernel(mem_ref, ctl_ref, src_ref, dst_ref, sizes_ref,
               omem_ref, octl_ref, buf_ref):
        p = pl.program_id(0)
        s = pl.program_id(1)

        @pl.when((p == 0) & (s == 0))
        def _first():
            buf_ref[...] = jnp.zeros((M, maxw), jnp.int32)

        @pl.when(p == 0)
        def _stage():  # first visit of shard s: boundary state in
            omem_ref[...] = mem_ref[...]
            octl_ref[...] = ctl_ref[...]

        srcv = src_ref[...]
        dstv = dst_ref[...]
        sizv = sizes_ref[...]
        valid = (srcv >= 0) & (dstv >= 0)

        @pl.when(p == 0)
        def _extract():
            sel = valid & (srcv // Ws == s)
            local = jnp.where(sel, srcv - s * Ws, -1)
            nm, nbuf = defrag.extract_math(
                scfg, kind, family, omem_ref[0, :], octl_ref[0, :],
                local, sizv, sel, buf_ref[...])
            omem_ref[0, :] = nm
            buf_ref[...] = nbuf

        @pl.when(p == 1)
        def _insert():
            sel = valid & (dstv // Ws == s)
            local = jnp.where(sel, dstv - s * Ws, -1)
            nm, nc2 = defrag.insert_rebuild_math(
                scfg, kind, family, omem_ref[0, :], octl_ref[0, :],
                local, sizv, sel, buf_ref[...])
            omem_ref[0, :] = nm
            octl_ref[0, :] = nc2

    lane = pl.BlockSpec((M,), lambda p, s: (0,))
    outs = pl.pallas_call(
        kernel,
        grid=(2, S),
        in_specs=[pl.BlockSpec((1, Mw), lambda p, s: (s, 0)),
                  pl.BlockSpec((1, Cw), lambda p, s: (s, 0)),
                  lane, lane, lane],
        out_specs=[pl.BlockSpec((1, Mw), lambda p, s: (s, 0)),
                   pl.BlockSpec((1, Cw), lambda p, s: (s, 0)),
                   pl.BlockSpec((M, maxw), lambda p, s: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, Mw), jnp.int32),
                   jax.ShapeDtypeStruct((S, Cw), jnp.int32),
                   jax.ShapeDtypeStruct((M, maxw), jnp.int32)],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(mem, ctl, src.astype(jnp.int32), dst.astype(jnp.int32),
      sizes.astype(jnp.int32))
    return outs[0], outs[1]


# --------------------------------------------------------------------------
# blocked lowering: per-region waves under the §8 discipline
# --------------------------------------------------------------------------
#
# Defrag's own region treatment (alloc/free's Region.blocking does not
# apply — a wave writes the heap for every chunk family):
#
#   heap, bitmap                      hbm (ANY; word/window DMAs)
#   pool_store, free_count,
#   chunk_class                       resident VMEM blocks
#   queue_store / directory           one row per class grid step
#
# Every region is both read and written; hbm regions are input/output-
# aliased.  The carry buffer rides as one grid-persistent VMEM block.

_HBM = ("heap", "bitmap")
_RESIDENT = ("pool_store", "free_count", "chunk_class")


def _move_lane_prep(cfg, offsets, sizes, sel_i):
    C = cfg.num_classes
    cls = size_to_class_device(cfg, sizes)
    valid = (sel_i != 0) & (offsets >= 0) & (cls < C)
    pw = jnp.left_shift(cfg.page_words(0), cls % C).astype(jnp.int32)
    return valid.astype(jnp.int32), pw


def _rot(win, shift, maxw):
    """``win`` rotated left by ``shift`` (traced): out[k] = win[(k +
    shift) % maxw] — the windowed-copy alignment primitive."""
    padded = jnp.concatenate([win, win])
    return jax.lax.dynamic_slice(padded, (shift,), (maxw,))


def _extract_moves(cfg, lay, E, buf_ref, src, sizes, sel_i):
    """Blocked extract: per move, stage its heap window, align, write
    its carry-buffer row, clear its bitmap bit, bump its chunk's free
    count — ``defrag.extract_math`` at window/word granularity."""
    W = cfg.total_words
    wpc = cfg.words_per_chunk
    bw = cfg.bitmap_words_per_chunk
    maxw = wpc
    M = src.shape[0]
    valid_i, pw_v = _move_lane_prep(cfg, src, sizes, sel_i)
    heap_ref = E["heap"]
    bitmap_ref = E["bitmap"]
    fc_ref = E["free_count"]
    kk = _iota(maxw)

    def move(i, _):
        g = _take(valid_i, i) != 0
        s = jnp.where(g, _take(src, i), 0)
        pw = _take(pw_v, i)
        bs = jnp.clip(s, 0, W - maxw)
        win = _vec_ld(heap_ref, bs, maxw)
        vals = _rot(win, s - bs, maxw)            # vals[k] = heap[s+k]
        old = pl.load(buf_ref, (pl.ds(i * maxw, maxw),))
        new = jnp.where(g & (kk < pw), vals, old)
        pl.store(buf_ref, (pl.ds(i * maxw, maxw),), new)
        # clear the source bit, return the page to its chunk
        ch = s // wpc
        pg = (s % wpc) // pw
        a = ch * bw + pg // 32
        row_u = jax.lax.bitcast_convert_type(
            jnp.reshape(_ld_if(bitmap_ref, a, g, 0), (1,)), jnp.uint32)
        bit = jnp.uint32(1) << (pg % 32).astype(jnp.uint32)
        _st_if(bitmap_ref, a,
               jax.lax.bitcast_convert_type(row_u - bit, jnp.int32)[0], g)
        cur = _ld_if(fc_ref, ch, g, 0)
        _st_if(fc_ref, ch, cur + 1, g)
        return 0

    jax.lax.fori_loop(0, M, move, 0)


def _insert_moves(cfg, lay, E, buf_ref, dst, sizes, sel_i):
    """Blocked insert: per move, place its carry-buffer row into the
    destination window (RMW), claim the destination chunk if it is
    still unbound (bitmap reset, full count, bind — alloc's from-pool
    path, which cross-shard rebalance moves rely on), set the bit,
    take the page from the chunk's free count —
    ``defrag.insert_rebuild_math``'s insert half."""
    C = cfg.num_classes
    W = cfg.total_words
    wpc = cfg.words_per_chunk
    bw = cfg.bitmap_words_per_chunk
    maxw = wpc
    M = dst.shape[0]
    valid_i, pw_v = _move_lane_prep(cfg, dst, sizes, sel_i)
    cls_v = size_to_class_device(cfg, sizes)
    heap_ref = E["heap"]
    bitmap_ref = E["bitmap"]
    fc_ref = E["free_count"]
    cc_ref = E["chunk_class"]
    kk = _iota(maxw)

    def move(i, _):
        g = _take(valid_i, i) != 0
        d = jnp.where(g, _take(dst, i), 0)
        pw = _take(pw_v, i)
        cls = _take(cls_v, i)
        vals = pl.load(buf_ref, (pl.ds(i * maxw, maxw),))
        bd = jnp.clip(d, 0, W - maxw)
        sh = d - bd
        dwin = _vec_ld(heap_ref, bd, maxw)
        placed = _rot(vals, maxw - sh, maxw)      # placed[sh+k] = vals[k]
        mask = g & (kk >= sh) & (kk < sh + pw)
        _vec_st_if(heap_ref, bd, jnp.where(mask, placed, dwin), g)
        ch = d // wpc
        # claim a still-unbound destination chunk (sequential per-move:
        # the first move targeting it claims, later ones see it bound)
        claim = g & (_ld_if(cc_ref, ch, g, 0) < 0)
        ppc = jnp.right_shift(cfg.max_pages_per_chunk,
                              jnp.clip(cls, 0, C - 1))
        _vec_st_if(bitmap_ref, ch * bw, jnp.zeros(bw, jnp.int32), claim)
        _st_if(fc_ref, ch, ppc, claim)
        _st_if(cc_ref, ch, cls, claim)
        pg = (d % wpc) // pw
        a = ch * bw + pg // 32
        row_u = jax.lax.bitcast_convert_type(
            jnp.reshape(_ld_if(bitmap_ref, a, g, 0), (1,)), jnp.uint32)
        bit = jnp.uint32(1) << (pg % 32).astype(jnp.uint32)
        _st_if(bitmap_ref, a,
               jax.lax.bitcast_convert_type(row_u + bit, jnp.int32)[0], g)
        cur = _ld_if(fc_ref, ch, g, 0)
        _st_if(fc_ref, ch, cur - 1, g)
        return 0

    jax.lax.fori_loop(0, M, move, 0)


def _unbind_and_pool(cfg, lay, E, octl):
    """Unbind fully-free chunks and re-prime the pool ring with every
    unbound id (ascending) — the vectorized resident-block half of the
    oracle's rebuild."""
    C = cfg.num_classes
    nc = cfg.num_chunks
    cc_ref = E["chunk_class"]
    fc_ref = E["free_count"]
    pool_ref = E["pool_store"]
    cc = cc_ref[...]
    fc = fc_ref[...]
    full_count = jnp.right_shift(cfg.max_pages_per_chunk,
                                 jnp.clip(cc, 0, C - 1))
    cc2 = jnp.where((cc >= 0) & (fc == full_count), -1, cc)
    cc_ref[...] = cc2
    unbound = cc2 < 0
    ui = unbound.astype(jnp.int32)
    rank = jnp.cumsum(ui) - ui
    k = jnp.sum(ui)
    ids = _iota(nc)
    onehot = unbound[None, :] & (rank[None, :] == ids[:, None])
    row = jnp.sum(jnp.where(onehot, ids[None, :], 0), axis=1)
    pool_ref[...] = jnp.where(ids < k, row, NULL)
    _st(octl, lay.off_pool_front, 0)
    _st(octl, lay.off_pool_back, k)


def _rebuild_class(cfg, lay, family, c, E, octl):
    """Rebuild class ``c``'s queue from the surviving live chunks —
    the per-class grid step of the oracle's class-major rebuild (fresh
    counters, one fresh segment for virtualized families, then the
    ascending-id enqueue of every bound chunk with free pages)."""
    C = cfg.num_classes
    nc = cfg.num_chunks
    wpc = cfg.words_per_chunk
    W = cfg.total_words
    spc = cfg.slots_per_segment(family)
    max_segs = lay.max_segs
    m = nc // spc + 1

    cc = E["chunk_class"][...]
    fc = E["free_count"][...]
    live = (cc == c) & (fc > 0)
    ai = live.astype(jnp.int32)
    rank_v = jnp.cumsum(ai) - ai
    cnt = jnp.sum(ai)
    pool_ref = E["pool_store"]
    heap_ref = E.get("heap")
    qrow = E.get("queue_store")
    dir_ref = E.get("directory")

    _st(octl, lay.off_front + c, 0)
    _st(octl, lay.off_back + c, 0)

    if family == "ring":
        cap = qrow.shape[1]
        qrow[0, :] = jnp.full((cap,), NULL, jnp.int32)
        _st(octl, lay.off_head + c, 0)
        _st(octl, lay.off_tail + c, 0)

        def put(kk, _):
            _row_st_if(qrow, _take(rank_v, kk) % cap, kk,
                       _take(ai, kk) != 0)
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
        _st(octl, lay.off_back + c, cnt)
        return

    # virtualized families: one fresh segment, popped in class order
    dir_ref[0, :] = jnp.full((max_segs,), NULL, jnp.int32)
    s0 = _pool_pop1(octl, pool_ref, lay, jnp.asarray(True))
    if family == "va":
        _row_st_if(dir_ref, 0, s0, jnp.asarray(True))
    else:  # vl: terminate the fresh head segment
        w0 = s0 * wpc
        _st_if(heap_ref, w0, NULL, (w0 >= 0) & (w0 < W))
    _st(octl, lay.off_head + c, s0)
    _st(octl, lay.off_tail + c, s0)

    if family == "va":
        _va_grow(octl, pool_ref, dir_ref, lay, spc, jnp.int32(0), cnt, m)

        def put(kk, _):
            v = _take(rank_v, kk)
            seg = _row_ld(dir_ref, (v // spc) % max_segs)
            word = seg * wpc + v % spc
            _st_if(heap_ref, word, kk,
                   (_take(ai, kk) != 0) & (word >= 0) & (word < W))
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
    else:  # vl
        new_chunks, new_tail = _vl_grow(octl, pool_ref, heap_ref, lay,
                                        spc, wpc, W, s0, jnp.int32(0),
                                        cnt, m)
        seg_vec = jnp.stack([s0] + new_chunks)

        def put(kk, _):
            v = _take(rank_v, kk)
            seg = _take(seg_vec, v // spc)
            word = seg * wpc + 1 + v % spc
            _st_if(heap_ref, word, kk,
                   (_take(ai, kk) != 0) & (word >= 0) & (word < W))
            return 0

        jax.lax.fori_loop(0, nc, put, 0)
        _st(octl, lay.off_tail + c, new_tail)
    _st(octl, lay.off_back + c, cnt)


def _defrag_regions(lay):
    """(region name, treatment) pairs for this layout, in region order."""
    out = []
    for r in lay.regions:
        if r.name in _HBM:
            out.append((r.name, "hbm"))
        elif r.name in _RESIDENT:
            out.append((r.name, "resident"))
        else:
            out.append((r.name, "row"))
    return out


@functools.partial(jax.jit,
                   static_argnames=("cfg", "kind", "family", "interpret"))
def arena_defrag_txn_blocked(cfg, kind, family, mem, ctl, src, dst,
                             sizes, *, interpret: bool = False):
    """Region-blocked migration wave: ONE pallas_call over the class
    grid, bit-identical to ``defrag.migrate_math`` and to the whole
    lowering.  Returns ``(new_mem, new_ctl)``."""
    assert kind == "chunk", "defrag waves exist for chunk kinds only"
    lay = arena.layout(cfg, kind, family)
    parts = arena.split(lay, mem)
    regions = _defrag_regions(lay)
    names = [nm for nm, _ in regions]
    C = cfg.num_classes
    M = src.shape[0]
    maxw = cfg.words_per_chunk
    lanes = (src.astype(jnp.int32), dst.astype(jnp.int32),
             sizes.astype(jnp.int32))

    def _arr(nm, treat):
        r = lay.region(nm)
        return (parts[nm].reshape(r.shape) if treat == "row"
                else parts[nm])

    def _spec(nm, treat):
        r = lay.region(nm)
        if treat == "row":
            return pl.BlockSpec((1,) + r.shape[1:], lambda c, t: (c, 0))
        if treat == "resident":
            return pl.BlockSpec((r.words,), lambda c, t: (0,))
        return pl.BlockSpec(memory_space=pltpu.ANY)

    def _oshape(nm, treat):
        r = lay.region(nm)
        shape = r.shape if treat == "row" else (r.words,)
        return jax.ShapeDtypeStruct(shape, jnp.int32)

    lane_spec = pl.BlockSpec((M,), lambda c, t: (0,))
    in_arrays = list(lanes) + [_arr(nm, tr) for nm, tr in regions]
    in_specs = [lane_spec] * 3 + [_spec(nm, tr) for nm, tr in regions]
    out_specs = [_spec(nm, tr) for nm, tr in regions]
    out_shapes = [_oshape(nm, tr) for nm, tr in regions]
    out_specs.append(pl.BlockSpec((lay.ctl_words,), lambda c, t: (0,)))
    out_shapes.append(jax.ShapeDtypeStruct((lay.ctl_words,), jnp.int32))
    out_specs.append(pl.BlockSpec((M * maxw,), lambda c, t: (0,)))
    out_shapes.append(jax.ShapeDtypeStruct((M * maxw,), jnp.int32))

    n_r = len(regions)
    aliases = {1 + 3 + i: i for i, (nm, tr) in enumerate(regions)
               if tr == "hbm"}

    def kernel(ctl_ref, *refs):
        in_refs, out_refs = refs[:3 + n_r], refs[3 + n_r:]
        srcv, dstv, sizv = (r[...] for r in in_refs[:3])
        R = dict(zip(names, in_refs[3:]))
        O = dict(zip(names, out_refs[:n_r]))
        octl = out_refs[n_r]
        buf_ref = out_refs[n_r + 1]
        c = pl.program_id(0)
        E = O

        @pl.when(c == 0)
        def _init():
            octl[...] = ctl_ref[...]
            buf_ref[...] = jnp.zeros((M * maxw,), jnp.int32)
            for nm, tr in regions:
                if tr == "resident" or (tr == "hbm" and interpret):
                    # hbm regions are input/output-aliased: the copy is
                    # interpret-only, as in alloc_txn_blocked._txn_call
                    O[nm][...] = R[nm][...]
            sel = ((srcv >= 0) & (dstv >= 0)).astype(jnp.int32)
            _extract_moves(cfg, lay, E, buf_ref, srcv, sizv, sel)
            _insert_moves(cfg, lay, E, buf_ref, dstv, sizv, sel)
            _unbind_and_pool(cfg, lay, E, octl)

        _rebuild_class(cfg, lay, family, c, E, octl)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(C,),
        in_specs=in_specs, out_specs=out_specs)
    outs = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        input_output_aliases=aliases, interpret=interpret,
    )(ctl.astype(jnp.int32), *in_arrays)

    new_parts = dict(parts)
    for nm, val in zip(names, outs[:n_r]):
        new_parts[nm] = val
    return arena.join(lay, new_parts), outs[n_r]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_shards", "kind", "family",
                                    "interpret"))
def sharded_arena_defrag_txn_blocked(cfg, num_shards, kind, family, mem,
                                     ctl, src, dst, sizes, *,
                                     interpret: bool = False):
    """Sharded region-blocked wave: ONE pallas_call over the
    (phase, shard, class) grid — §9 region stacking, phase 0 extract /
    phase 1 insert+rebuild.  Returns ``(new_mem, new_ctl)``."""
    assert kind == "chunk", "defrag waves exist for chunk kinds only"
    from repro.core import shards as _shards  # lazy: kernels <-> core

    S = num_shards
    scfg = _shards.shard_config(cfg, S)
    slay = _shards.layout(cfg, S, kind, family)
    lay = slay.shard
    Ws = scfg.total_words
    C = scfg.num_classes
    Cw = lay.ctl_words
    M = src.shape[0]
    maxw = scfg.words_per_chunk
    parts = _shards.split_regions(slay, mem)
    regions = _defrag_regions(lay)
    names = [nm for nm, _ in regions]
    hbm_words = {nm: lay.region(nm).words for nm, tr in regions
                 if tr == "hbm"}
    lanes = (src.astype(jnp.int32), dst.astype(jnp.int32),
             sizes.astype(jnp.int32))

    def _arr(nm, tr):
        r = lay.region(nm)
        p = parts[nm]
        if tr == "row":
            return p.reshape(S * r.shape[0], r.shape[1])
        return p.reshape(S * r.words)

    def _spec(nm, tr):
        r = lay.region(nm)
        if tr == "row":
            return pl.BlockSpec((1, r.shape[1]),
                                lambda p, s, c, t, C=C: (s * C + c, 0))
        if tr == "resident":
            return pl.BlockSpec((r.words,), lambda p, s, c, t: (s,))
        return pl.BlockSpec(memory_space=pltpu.ANY)

    def _oshape(nm, tr):
        r = lay.region(nm)
        if tr == "row":
            return jax.ShapeDtypeStruct((S * r.shape[0], r.shape[1]),
                                        jnp.int32)
        return jax.ShapeDtypeStruct((S * r.words,), jnp.int32)

    lane_spec = pl.BlockSpec((M,), lambda p, s, c, t: (0,))
    in_arrays = list(lanes) + [_arr(nm, tr) for nm, tr in regions]
    in_specs = [lane_spec] * 3 + [_spec(nm, tr) for nm, tr in regions]
    out_specs = [_spec(nm, tr) for nm, tr in regions]
    out_shapes = [_oshape(nm, tr) for nm, tr in regions]
    out_specs.append(pl.BlockSpec((Cw,), lambda p, s, c, t: (s,)))
    out_shapes.append(jax.ShapeDtypeStruct((S * Cw,), jnp.int32))
    out_specs.append(pl.BlockSpec((M * maxw,), lambda p, s, c, t: (0,)))
    out_shapes.append(jax.ShapeDtypeStruct((M * maxw,), jnp.int32))

    n_r = len(regions)
    aliases = {1 + 3 + i: i for i, (nm, tr) in enumerate(regions)
               if tr == "hbm"}

    def kernel(ctl_ref, *refs):
        in_refs, out_refs = refs[:3 + n_r], refs[3 + n_r:]
        srcv, dstv, sizv = (r[...] for r in in_refs[:3])
        R = dict(zip(names, in_refs[3:]))
        O = dict(zip(names, out_refs[:n_r]))
        octl = out_refs[n_r]
        buf_ref = out_refs[n_r + 1]
        p = pl.program_id(0)
        s = pl.program_id(1)
        c = pl.program_id(2)

        @pl.when((p == 0) & (s == 0) & (c == 0))
        def _once():
            buf_ref[...] = jnp.zeros((M * maxw,), jnp.int32)
            if interpret:
                for nm, tr in regions:
                    if tr == "hbm":
                        O[nm][...] = R[nm][...]

        @pl.when((p == 0) & (c == 0))
        def _per_shard():
            octl[...] = pl.load(ctl_ref, (pl.ds(s * Cw, Cw),))
            for nm, tr in regions:
                if tr == "resident":
                    O[nm][...] = R[nm][...]

        @pl.when(p == 0)
        def _stage_rows():
            for nm, tr in regions:
                if tr == "row":
                    O[nm][0, :] = R[nm][0, :]

        def _wrap(nm, tr, ref):
            if tr == "hbm":
                return _ShardView(ref, s * hbm_words[nm])
            return ref

        E = {nm: _wrap(nm, tr, O[nm]) for nm, tr in regions}
        valid = (srcv >= 0) & (dstv >= 0)

        @pl.when((p == 0) & (c == 0))
        def _extract():
            sel = (valid & (srcv // Ws == s)).astype(jnp.int32)
            local = jnp.where(sel != 0, srcv - s * Ws, -1)
            _extract_moves(scfg, lay, E, buf_ref, local, sizv, sel)

        @pl.when((p == 1) & (c == 0))
        def _insert():
            sel = (valid & (dstv // Ws == s)).astype(jnp.int32)
            local = jnp.where(sel != 0, dstv - s * Ws, -1)
            _insert_moves(scfg, lay, E, buf_ref, local, sizv, sel)
            _unbind_and_pool(scfg, lay, E, octl)

        @pl.when(p == 1)
        def _rebuild():
            _rebuild_class(scfg, lay, family, c, E, octl)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(2, S, C),
        in_specs=in_specs, out_specs=out_specs)
    outs = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        input_output_aliases=aliases, interpret=interpret,
    )(ctl.reshape(-1).astype(jnp.int32), *in_arrays)

    new_parts = dict(parts)
    for nm, val in zip(names, outs[:n_r]):
        new_parts[nm] = val.reshape(S, -1)
    return _shards.join_regions(slay, new_parts), outs[n_r].reshape(S, Cw)
