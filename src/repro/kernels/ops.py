"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU (this container,
and CI) they run in ``interpret=True`` mode so every call is validated
against the compiled path's exact semantics.  ``ref.py`` carries the
pure-jnp oracles used by the tests.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import alloc_txn as _alloc_txn
from repro.kernels.bitmap_select import bitmap_select as _bitmap_select
from repro.kernels.paged_attention import paged_attention as _paged_attention
from repro.kernels.ring_window import ring_window as _ring_window
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ring_window(store, front, counts, *, m: int):
    return _ring_window(store, front, counts, m=m, interpret=_interpret())


# ---- fused allocator transactions (kernels/alloc_txn.py) -------------------

def ring_txn_pop(store, front, back, cls, valid, *, limit: bool):
    return _alloc_txn.ring_txn_pop(store, front, back, cls, valid,
                                   limit=limit, interpret=_interpret())


def ring_txn_push(store, back, cls, vals, valid):
    return _alloc_txn.ring_txn_push(store, back, cls, vals, valid,
                                    interpret=_interpret())


def chunk_txn_claim(row, take, *, ppc: int):
    return _alloc_txn.chunk_txn_claim(row, take, ppc=ppc,
                                      interpret=_interpret())


LOWERINGS = ("whole", "blocked", "auto")


def resolve_lowering(lowering: str = "auto") -> str:
    """Concrete kernel lowering for the fused arena transactions.

    ``whole``    the kernel takes the full ``mem`` image as one ref —
                 simplest, but only lowers while the arena fits VMEM;
    ``blocked``  the region-blocked compiled lowering (kernels/
                 alloc_txn_blocked.py): per-region BlockSpecs, class-row
                 grid, scalar-prefetched control block (DESIGN.md §8);
    ``auto``     honours ``REPRO_ALLOC_LOWERING`` (CI forces the
                 blocked matrix through it), else picks ``blocked`` on
                 TPU — where whole-arena refs stop lowering at serving
                 sizes — and ``whole`` in CPU interpret mode.
    """
    if lowering not in LOWERINGS:
        raise ValueError(
            f"unknown lowering {lowering!r}; pick from {LOWERINGS}")
    if lowering != "auto":
        return lowering
    env = os.environ.get("REPRO_ALLOC_LOWERING", "")
    if env:
        if env not in ("whole", "blocked"):
            raise ValueError(
                f"REPRO_ALLOC_LOWERING={env!r}; expected whole|blocked")
        return env
    return "blocked" if jax.default_backend() == "tpu" else "whole"


def arena_alloc_txn(cfg, kind, family, mem, ctl, sizes_bytes, mask,
                    lowering: str = "auto"):
    """Whole alloc transaction (any variant) in one pallas_call."""
    if resolve_lowering(lowering) == "blocked":
        from repro.kernels import alloc_txn_blocked as _blk
        return _blk.arena_alloc_txn_blocked(cfg, kind, family, mem, ctl,
                                            sizes_bytes, mask,
                                            interpret=_interpret())
    return _alloc_txn.arena_alloc_txn(cfg, kind, family, mem, ctl,
                                      sizes_bytes, mask,
                                      interpret=_interpret())


def arena_free_txn(cfg, kind, family, mem, ctl, offsets_words,
                   sizes_bytes, mask, lowering: str = "auto"):
    """Whole free transaction (any variant) in one pallas_call."""
    if resolve_lowering(lowering) == "blocked":
        from repro.kernels import alloc_txn_blocked as _blk
        return _blk.arena_free_txn_blocked(cfg, kind, family, mem, ctl,
                                           offsets_words, sizes_bytes,
                                           mask, interpret=_interpret())
    return _alloc_txn.arena_free_txn(cfg, kind, family, mem, ctl,
                                     offsets_words, sizes_bytes, mask,
                                     interpret=_interpret())


def sharded_arena_alloc_txn(cfg, num_shards, kind, family, mem, ctl,
                            sizes_bytes, mask, home, walk,
                            lowering: str = "auto"):
    """Whole SHARDED alloc transaction (overflow-walk schedule gridded
    over per-shard slabs, core/shards.py) in one pallas_call."""
    if resolve_lowering(lowering) == "blocked":
        from repro.kernels import alloc_txn_blocked as _blk
        return _blk.sharded_arena_alloc_txn_blocked(
            cfg, num_shards, kind, family, mem, ctl, sizes_bytes, mask,
            home, walk, interpret=_interpret())
    return _alloc_txn.sharded_arena_alloc_txn(
        cfg, num_shards, kind, family, mem, ctl, sizes_bytes, mask,
        home, walk, interpret=_interpret())


def sharded_arena_free_txn(cfg, num_shards, kind, family, mem, ctl,
                           offsets_words, sizes_bytes, mask,
                           lowering: str = "auto"):
    """Whole SHARDED free transaction in one pallas_call."""
    if resolve_lowering(lowering) == "blocked":
        from repro.kernels import alloc_txn_blocked as _blk
        return _blk.sharded_arena_free_txn_blocked(
            cfg, num_shards, kind, family, mem, ctl, offsets_words,
            sizes_bytes, mask, interpret=_interpret())
    return _alloc_txn.sharded_arena_free_txn(
        cfg, num_shards, kind, family, mem, ctl, offsets_words,
        sizes_bytes, mask, interpret=_interpret())


# ---- fused defragmentation waves (kernels/defrag_txn.py) -------------------

def arena_defrag_txn(cfg, kind, family, mem, ctl, src, dst, sizes,
                     lowering: str = "auto"):
    """One whole migration wave (DESIGN.md §10) in one pallas_call."""
    from repro.kernels import defrag_txn as _dfg
    if resolve_lowering(lowering) == "blocked":
        return _dfg.arena_defrag_txn_blocked(cfg, kind, family, mem, ctl,
                                             src, dst, sizes,
                                             interpret=_interpret())
    return _dfg.arena_defrag_txn(cfg, kind, family, mem, ctl, src, dst,
                                 sizes, interpret=_interpret())


def sharded_arena_defrag_txn(cfg, num_shards, kind, family, mem, ctl,
                             src, dst, sizes, lowering: str = "auto"):
    """One SHARDED migration wave (extract/insert phases gridded over
    the shards) in one pallas_call."""
    from repro.kernels import defrag_txn as _dfg
    if resolve_lowering(lowering) == "blocked":
        return _dfg.sharded_arena_defrag_txn_blocked(
            cfg, num_shards, kind, family, mem, ctl, src, dst, sizes,
            interpret=_interpret())
    return _dfg.sharded_arena_defrag_txn(
        cfg, num_shards, kind, family, mem, ctl, src, dst, sizes,
        interpret=_interpret())


def count_pallas_calls(closed_jaxpr) -> int:
    """Number of ``pallas_call`` eqns anywhere in a jaxpr (descending
    into sub-jaxprs in eqn params).  The single source of truth for the
    one-kernel-per-transaction assertions in tests/test_alloc_txn_parity
    and the ``launches_per_txn`` proof in benchmarks/run.py."""
    import jax.core as jc

    def jaxprs_in(val):
        if isinstance(val, jc.ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, jc.Jaxpr):
            yield val
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from jaxprs_in(v)
        elif isinstance(val, dict):
            for v in val.values():
                yield from jaxprs_in(v)

    seen = 0
    stack = [closed_jaxpr.jaxpr]
    while stack:
        jx = stack.pop()
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                seen += 1
            for val in eqn.params.values():
                stack.extend(jaxprs_in(val))
    return seen


def bitmap_select(words, k, *, block_words: int = 32):
    return _bitmap_select(words, k, block_words=block_words,
                          interpret=_interpret())


def bitmap_select_indices(words, k, *, max_k: int):
    """Compact the dense rank map to the first ``max_k`` bit indices."""
    dense = bitmap_select(words, k)
    order = jnp.argsort(jnp.where(dense >= 0, dense, jnp.int32(2**30)))
    idx = order[:max_k]
    valid = dense[idx] >= 0
    return jnp.where(valid, idx, -1).astype(jnp.int32), valid


def paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                    wpp=None):
    """Paged decode attention; ``wpp`` set means ``page_table`` holds
    raw arena word offsets (page id derived at DMA-issue time — see
    kernels/paged_attention.py)."""
    return _paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                            wpp=wpp,
                            interpret=_interpret())


def ssd_scan(x, dt, a, b, c, h0=None, *, chunk: int = 64):
    return _ssd_scan(x, dt, a, b, c, h0, chunk=chunk,
                     interpret=_interpret())
