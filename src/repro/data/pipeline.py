"""Deterministic, shard-aware, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — so restart
recovery is exact (no iterator state to checkpoint) and every host
produces only its own slice of the global batch.  Documents are
variable-length (Zipf-ish) and packed into fixed windows through a
page-granular staging buffer drawn from the Ouroboros allocator — the
training-side use of the paper's technique (variable-sized documents =
variable-sized allocations).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 1
    shard_index: int = 0
    num_shards: int = 1


def _doc_lengths(rng, total_needed, mean_len):
    """Zipf-flavored document lengths (many short, few long)."""
    out = []
    got = 0
    while got < total_needed:
        ln = int(min(np.ceil(rng.pareto(1.5) * mean_len * 0.5) + 16,
                     8 * mean_len))
        out.append(ln)
        got += ln
    return out


def batch_at(cfg: ModelConfig, shape: ShapeConfig, dcfg: DataConfig,
             step: int, local_batch: Optional[int] = None):
    """The global batch for ``step``, restricted to this shard's rows.

    Returns a dict matching the model's batch convention; targets are
    next-token with −100 → masked (we use −1) at document boundaries."""
    b_global = shape.global_batch
    local_batch = local_batch or b_global // dcfg.num_shards
    row0 = dcfg.shard_index * local_batch
    seq = shape.seq_len

    toks = np.empty((local_batch, seq + 1), np.int32)
    for r in range(local_batch):
        rng = np.random.default_rng(
            (dcfg.seed, step, row0 + r))  # pure function of coordinates
        lens = _doc_lengths(rng, seq + 1, dcfg.mean_doc_len)
        row = []
        for ln in lens:
            doc = rng.integers(2, cfg.vocab_size, ln - 1, dtype=np.int32)
            row.extend(doc.tolist())
            row.append(dcfg.eos_id)
        toks[r] = np.asarray(row[:seq + 1], np.int32)

    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:].copy()}
    if cfg.modality == "vision":
        rng = np.random.default_rng((dcfg.seed, step, 10**6))
        batch["mm_embeds"] = rng.standard_normal(
            (local_batch, seq, cfg.d_model)).astype(np.float32) * 0.02
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None, None],
                              (local_batch, 3, seq)).copy()
        batch["positions"] = pos
    if cfg.modality == "audio":
        rng = np.random.default_rng((dcfg.seed, step, 10**6 + 1))
        batch["src_embeds"] = rng.standard_normal(
            (local_batch, seq, cfg.d_model)).astype(np.float32) * 0.1
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    import jax
    import jax.numpy as jnp
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": sds((b, s), jnp.int32),
           "targets": sds((b, s), jnp.int32)}
    if cfg.modality == "vision":
        out["mm_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        out["positions"] = sds((b, 3, s), jnp.int32)
    if cfg.modality == "audio":
        out["src_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    return out
