"""Observability layer: in-kernel allocator telemetry, a metrics
registry with Prometheus/JSON exposition, and Chrome-trace spans.

Import surface is kept light on purpose — ``repro.core.transactions``
pulls :mod:`repro.obs.telemetry` into every transaction, so this
package must never import the serving stack back.

- :mod:`repro.obs.telemetry` — bit-exact update math + host decoder
  for the ctl telemetry region (DESIGN.md §14);
- :mod:`repro.obs.metrics` — counters/gauges/histograms with labels,
  Prometheus text exposition and JSON;
- :mod:`repro.obs.trace` — ``trace_event`` spans for engine phases,
  viewable in Perfetto / chrome://tracing.
"""
