"""Metrics registry: labelled counters/gauges/histograms with
Prometheus text exposition and JSON export.

Pure stdlib — no client library dependency.  The registry is the one
funnel every host-side reading publishes through: ``ServingEngine``
stats and fragmentation gauges, drained ctl telemetry words
(obs/telemetry.py), replay latency summaries, and ``StepMonitor``
EWMA/straggler readings.  ``launch/serve.py --metrics-file`` writes
the exposition periodically; ``scripts/obs_dump.py`` pretty-prints it.

Counters here mirror monotonic device words, so they support both
``inc()`` (host-observed events) and ``set()`` (re-publishing an
absolute device total — the Prometheus value is a total either way).
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, Iterable, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Default latency buckets (milliseconds): decode ticks sit around
# 1–100 ms on CPU interpret mode, compile ticks in the seconds.
DEFAULT_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, 2500.0, 5000.0, 10000.0)


def _fmt(v) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


class _Hist:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +Inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class Metric:
    """One metric family; per-label-set samples live in ``samples``."""

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name, self.help, self.kind = name, help, kind
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets)
        self.samples: Dict[Tuple[str, ...], object] = {}

    def labels(self, **kw) -> "_Sample":
        if set(kw) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(kw)}, declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kw[ln]) for ln in self.labelnames)
        return _Sample(self, key)

    # label-less shorthands
    def inc(self, v: float = 1) -> None:
        self.labels().inc(v)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)


class _Sample:
    __slots__ = ("metric", "key")

    def __init__(self, metric: Metric, key: Tuple[str, ...]):
        self.metric, self.key = metric, key

    def inc(self, v: float = 1) -> None:
        if self.metric.kind == "histogram":
            raise TypeError(f"{self.metric.name} is a histogram")
        self.metric.samples[self.key] = \
            self.metric.samples.get(self.key, 0) + v

    def set(self, v: float) -> None:
        if self.metric.kind == "histogram":
            raise TypeError(f"{self.metric.name} is a histogram")
        self.metric.samples[self.key] = v

    def observe(self, v: float) -> None:
        if self.metric.kind != "histogram":
            raise TypeError(f"{self.metric.name} is not a histogram")
        h = self.metric.samples.get(self.key)
        if h is None:
            h = self.metric.samples[self.key] = _Hist(self.metric.buckets)
        h.observe(v)


class MetricsRegistry:
    """A set of metric families, exportable as Prometheus text or JSON."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _declare(self, name, help, kind, labelnames, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-declared with a different "
                    f"kind/label set")
            return m
        m = Metric(name, help, kind, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Metric:
        return self._declare(name, help, "counter", labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Metric:
        return self._declare(name, help, "gauge", labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Metric:
        return self._declare(name, help, "histogram", labelnames,
                             buckets=buckets)

    def __iter__(self) -> Iterable[Metric]:
        return iter(self._metrics.values())

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    # ---- exposition -------------------------------------------------------

    @staticmethod
    def _labelstr(names, values, extra=()) -> str:
        pairs = [f'{n}="{v}"' for n, v in zip(names, values)]
        pairs += [f'{n}="{v}"' for n, v in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        out = []
        for m in self._metrics.values():
            out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for key in sorted(m.samples):
                val = m.samples[key]
                if m.kind == "histogram":
                    acc = 0
                    for b, c in zip(list(val.buckets) + [math.inf],
                                    val.counts):
                        acc += c
                        ls = self._labelstr(m.labelnames, key,
                                            [("le", _fmt(b))])
                        out.append(f"{m.name}_bucket{ls} {acc}")
                    ls = self._labelstr(m.labelnames, key)
                    out.append(f"{m.name}_sum{ls} {_fmt(val.sum)}")
                    out.append(f"{m.name}_count{ls} {val.count}")
                else:
                    ls = self._labelstr(m.labelnames, key)
                    out.append(f"{m.name}{ls} {_fmt(val)}")
        return "\n".join(out) + "\n"

    def to_json(self) -> dict:
        doc = {}
        for m in self._metrics.values():
            samples = []
            for key in sorted(m.samples):
                val = m.samples[key]
                entry = {"labels": dict(zip(m.labelnames, key))}
                if m.kind == "histogram":
                    entry.update(sum=val.sum, count=val.count,
                                 buckets=dict(zip(
                                     [_fmt(b) for b in val.buckets],
                                     val.counts[:-1])),
                                 inf=val.counts[-1])
                else:
                    entry["value"] = val
                samples.append(entry)
            doc[m.name] = {"type": m.kind, "help": m.help,
                           "samples": samples}
        return doc

    def write(self, path: str, fmt: str = "prometheus") -> None:
        with open(path, "w") as f:
            if fmt == "json":
                json.dump(self.to_json(), f, indent=2, sort_keys=True)
                f.write("\n")
            else:
                f.write(self.to_prometheus())


_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                 # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""      # first label
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?" # more labels
    r" (-?[0-9.e+]+|\+Inf|NaN)$")


def validate_exposition(text: str) -> int:
    """Schema check for Prometheus text exposition (the CI nightly
    validator): every line is a HELP/TYPE comment or a well-formed
    sample, every sample's family was TYPE-declared first.  Returns the
    sample count; raises ``ValueError`` on the first bad line."""
    declared = {}
    samples = 0
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                raise ValueError(f"line {i}: malformed comment {line!r}")
            if parts[1] == "TYPE":
                if parts[3] not in ("counter", "gauge", "histogram",
                                    "summary", "untyped"):
                    raise ValueError(f"line {i}: bad type {parts[3]!r}")
                declared[parts[2]] = parts[3]
            continue
        if not _SAMPLE_RE.match(line):
            raise ValueError(f"line {i}: malformed sample {line!r}")
        fam = re.split(r"[{ ]", line, 1)[0]
        base = re.sub(r"_(bucket|sum|count)$", "", fam)
        if fam not in declared and base not in declared:
            raise ValueError(f"line {i}: sample {fam!r} has no TYPE")
        samples += 1
    if samples == 0:
        raise ValueError("exposition has no samples")
    return samples
