"""Chrome/Perfetto ``trace_event`` spans for serving-engine phases.

The engine wraps each phase — admission, prefill, tick, bulk grow,
defrag/rebalance wave, snapshot/restore, eviction, cancel — in a
:meth:`Tracer.span`; the result is a ``{"traceEvents": [...]}`` JSON
document loadable in Perfetto (https://ui.perfetto.dev) or
chrome://tracing.  Ticks that trigger a jit first-call (compile) are
tagged with category ``"compile"`` instead of ``"steady"`` so the two
populations separate visually and in queries — the same split
serve/replay.py uses for its latency summary (DESIGN.md §14).

``Tracer(enabled=False)`` (and the module-level :data:`NULL`) is a
no-op with the same surface, so instrumentation sites carry no
conditional logic.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import List, Optional

# The span taxonomy (name prefixes the engine emits).  DESIGN.md §14
# pins this tuple; tests validate emitted traces against it.
PHASES = ("admission", "prefill", "tick", "bulk_grow", "defrag_wave",
          "rebalance_wave", "snapshot", "restore", "eviction", "cancel")


class Tracer:
    """Collects complete ("ph": "X") duration events, microsecond
    timestamps from one monotonic origin."""

    def __init__(self, enabled: bool = True, pid: int = 0):
        self.enabled = enabled
        self.pid = pid
        self.events: List[dict] = []
        self._t0 = time.perf_counter_ns()

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    @contextmanager
    def span(self, name: str, cat: str = "engine", **args):
        if not self.enabled:
            yield
            return
        ts = self._now_us()
        try:
            yield
        finally:
            self.events.append({
                "name": name, "cat": cat, "ph": "X", "ts": ts,
                "dur": self._now_us() - ts, "pid": self.pid, "tid": 0,
                "args": args})

    def begin(self) -> float:
        """Timestamp for a deferred :meth:`complete` — for spans whose
        category is only known at close (compile vs steady ticks)."""
        return self._now_us() if self.enabled else 0.0

    def complete(self, name: str, ts: float, cat: str = "engine",
                 **args) -> None:
        """Close a span opened with :meth:`begin`."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "X", "ts": ts,
            "dur": self._now_us() - ts, "pid": self.pid, "tid": 0,
            "args": args})

    def instant(self, name: str, cat: str = "engine", **args) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "ts": self._now_us(),
            "pid": self.pid, "tid": 0, "s": "g", "args": args})

    def to_json(self) -> dict:
        return {"traceEvents": self.events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
            f.write("\n")


NULL = Tracer(enabled=False)


def validate_trace(doc, require_phases: bool = False) -> int:
    """Schema check for an emitted trace document (the CI nightly
    validator): a ``traceEvents`` list whose duration events carry the
    required Chrome trace-event keys, names from the engine taxonomy,
    and non-negative times.  With ``require_phases`` the trace must
    contain tick spans of BOTH categories — compile and steady — the
    acceptance criterion for replay traces.  Returns the event count;
    raises ``ValueError`` on the first violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a trace document (no traceEvents)")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents empty")
    cats_by_name = {}
    for i, ev in enumerate(events):
        for k in ("name", "cat", "ph", "ts", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i}: missing {k!r}")
        if ev["ph"] == "X":
            if "dur" not in ev or ev["dur"] < 0:
                raise ValueError(f"event {i}: bad duration")
        if ev["ts"] < 0:
            raise ValueError(f"event {i}: negative timestamp")
        base = ev["name"].split("/")[0]
        if base not in PHASES:
            raise ValueError(
                f"event {i}: name {ev['name']!r} outside the engine "
                f"span taxonomy {PHASES}")
        cats_by_name.setdefault(base, set()).add(ev["cat"])
    if require_phases:
        tick_cats = cats_by_name.get("tick", set())
        if not {"compile", "steady"} <= tick_cats:
            raise ValueError(
                f"trace does not separate compile from steady ticks "
                f"(tick categories seen: {sorted(tick_cats)})")
    return len(events)


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
