"""In-kernel allocator telemetry: the ctl-block accumulator region.

Every arena ctl block carries a fixed-offset telemetry region after the
core counters (``ArenaLayout.tele_fields()`` is the table; DESIGN.md
§14 renders it).  The words are updated *inside* the existing single
transaction ``pallas_call`` — zero extra launches — and, like every
other arena word, the jnp math here is the bit-exact oracle both
kernel lowerings must reproduce word for word
(tests/test_alloc_txn_parity.py compares full ctl blocks, telemetry
included).

Field semantics (all monotonic int32 totals, per arena / per shard):

``t_alloc[c]``     lanes granted an offset in class ``c``.
``t_free[c]``      lanes freed in class ``c``.
``t_fail[c]``      attempted-but-failed lanes in class ``c`` (masked
                   lanes and over-large sizes — class ≥ C — are not
                   attempts; under sharding a lane that fails on every
                   visited shard counts one failure per visit).
``t_wrap[c]``      full turns of class ``c``'s queue: crossings of
                   ``ArenaLayout.wrap_capacity`` by the monotonic
                   front/back counters.
``t_grow``         pool pops (chunk claims + va/vl segment grows).
``t_shrink``       pool pushes (chunk retires + segment reclaims).
``t_pool_wrap``    full turns of the free-chunk pool ring.
``t_walk[b]``      lanes served at overflow-walk attempt ``b`` (the
                   last bin collects deeper attempts; single-arena
                   traffic lands in bin 0).

Every delta is a pure function of observable transaction state — lane
inputs, granted offsets, and core-counter before/after values — which
is what makes the scalar per-class updates of the blocked lowering
provably equal to the vectorized oracle: per-step deltas telescope to
the whole-transaction delta.

Transactions that do not account traffic (defrag migration waves,
``compact``) carry the region through unchanged; a defrag wave is not
allocator traffic.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arena
from repro.core.heap import size_to_class_device


def _core(lay, ctl):
    return jax.lax.slice(ctl, (0,), (lay.core_ctl_words,))


def _vec(lay, ctl, off, w):
    return jax.lax.slice(ctl, (off,), (off + w,))


def _counter_deltas(lay, old_ctl, new_ctl):
    """Wrap/grow/shrink deltas from core-counter before/after values.

    Counters are raw monotonic positions, so ``// capacity`` crossings
    count full ring turns exactly — the same words both lowerings
    maintain, so the delta is implementation-independent.
    """
    C = lay.num_classes
    capw = lay.wrap_capacity
    nc = lay.cfg.num_chunks
    f0 = _vec(lay, old_ctl, lay.off_front, C)
    f1 = _vec(lay, new_ctl, lay.off_front, C)
    b0 = _vec(lay, old_ctl, lay.off_back, C)
    b1 = _vec(lay, new_ctl, lay.off_back, C)
    d_wrap = (f1 // capw - f0 // capw) + (b1 // capw - b0 // capw)
    pf0 = old_ctl[lay.off_pool_front]
    pf1 = new_ctl[lay.off_pool_front]
    pb0 = old_ctl[lay.off_pool_back]
    pb1 = new_ctl[lay.off_pool_back]
    d_grow = pf1 - pf0
    d_shrink = pb1 - pb0
    d_pool_wrap = (pf1 // nc - pf0 // nc) + (pb1 // nc - pb0 // nc)
    return d_wrap, d_grow, d_shrink, d_pool_wrap


def _per_class(lay, cls, sel):
    """Per-class count of selected lanes (vectorized one-hot sum)."""
    C = lay.num_classes
    onec = cls[:, None] == jnp.arange(C, dtype=jnp.int32)[None, :]
    return jnp.sum(onec & sel[:, None], axis=0).astype(jnp.int32)


def _apply(lay, new_ctl, d_alloc, d_free, d_fail, d_wrap, d_grow,
           d_shrink, d_pool_wrap, d_walk):
    tele = arena.tele_of(lay, new_ctl)
    delta = jnp.concatenate([
        d_alloc, d_free, d_fail, d_wrap,
        jnp.stack([d_grow, d_shrink, d_pool_wrap]), d_walk,
    ]).astype(jnp.int32)
    return jnp.concatenate([_core(lay, new_ctl), tele + delta])


def alloc_update(lay, old_ctl, new_ctl, sizes_bytes, mask, offs,
                 attempt=0):
    """Telemetry after one alloc transaction: ``new_ctl`` with the
    accumulator region advanced.  ``attempt`` is the overflow-walk
    attempt this call serves (0 for single-arena traffic); it may be a
    traced value — the sharded kernels pass their grid index."""
    C = lay.num_classes
    cls = size_to_class_device(lay.cfg, sizes_bytes)
    attempted = mask & (cls < C)
    served = attempted & (offs >= 0)
    failed = attempted & (offs < 0)
    d_alloc = _per_class(lay, cls, served)
    d_fail = _per_class(lay, cls, failed)
    d_wrap, d_grow, d_shrink, d_pool_wrap = _counter_deltas(
        lay, old_ctl, new_ctl)
    nbin = jnp.minimum(jnp.asarray(attempt, jnp.int32),
                       arena.TELE_WALK_BINS - 1)
    d_walk = jnp.where(
        jnp.arange(arena.TELE_WALK_BINS, dtype=jnp.int32) == nbin,
        jnp.sum(served).astype(jnp.int32), 0)
    zc = jnp.zeros(C, jnp.int32)
    return _apply(lay, new_ctl, d_alloc, zc, d_fail, d_wrap, d_grow,
                  d_shrink, d_pool_wrap, d_walk)


def free_update(lay, old_ctl, new_ctl, sizes_bytes, mask, offs):
    """Telemetry after one free transaction (no walk — an offset lives
    on exactly one shard)."""
    C = lay.num_classes
    cls = size_to_class_device(lay.cfg, sizes_bytes)
    freed = mask & (cls < C) & (offs >= 0)
    d_free = _per_class(lay, cls, freed)
    d_wrap, d_grow, d_shrink, d_pool_wrap = _counter_deltas(
        lay, old_ctl, new_ctl)
    zc = jnp.zeros(C, jnp.int32)
    zw = jnp.zeros(arena.TELE_WALK_BINS, jnp.int32)
    return _apply(lay, new_ctl, zc, d_free, zc, d_wrap, d_grow,
                  d_shrink, d_pool_wrap, zw)


# ---- host-side decoding ----------------------------------------------------

def decode(lay, ctl) -> Dict[str, np.ndarray]:
    """Drain one ctl block (or a sharded ``(S, ctl_words)`` stack) into
    named numpy arrays — the one host sync the observability layer
    needs per scrape.  Vector fields keep their per-class / per-bin
    axis; sharded inputs keep a leading shard axis."""
    c = np.asarray(ctl)
    return {name: c[..., off:off + w] if w > 1 else c[..., off]
            for name, off, w in lay.tele_fields()}


def totals(lay, ctl) -> Dict[str, int]:
    """Scalar totals over classes/bins/shards — the quick-look summary
    ``scripts/obs_dump.py`` and the engine stats publish."""
    return {name: int(v.sum()) for name, v in decode(lay, ctl).items()}
