"""Sharded, atomic, async checkpointing with keep-k retention.

Layout:   <dir>/step_<N>/<flat.leaf.path>.npy  +  meta.json
Atomicity: written into ``step_<N>.tmp`` then os.replace()'d — a crash
mid-save never corrupts the latest checkpoint (restore scans only
committed dirs).  ``save_async`` snapshots to host memory synchronously
(device buffers stay consistent) and writes on a daemon thread so the
step loop keeps running.  Restore can re-shard onto a different mesh:
pass target shardings and each leaf is device_put accordingly — the
elastic-rescale path (ft/runtime.py) reuses this.

Three hardening contracts the serving-arena snapshot path leans on:

- **Exact key→file map.**  Leaf filenames are sanitized leaf paths, so
  two distinct paths can collide after sanitization; ``_write``
  disambiguates colliding filenames with a ``__<n>`` suffix and
  ``meta.json`` records the exact mapping — ``restore`` reads files
  only through the map, never by re-sanitizing.
- **Raw-dtype fidelity.**  Non-native dtypes (bfloat16 & friends) save
  as raw bytes but ``np.load`` hands them back as void records;
  ``restore`` reinterprets through the dtype string recorded in
  ``meta.json``, so a bf16 KV heap round-trips bit-exactly.
- **Retention never races restore off a cliff.**  ``_retain`` always
  keeps the newest committed step (even ``keep=0``), and ``restore``
  falls back to the next-newest committed step when the one it
  selected vanished mid-read (the AsyncCheckpointer's daemon-thread
  keep-k sweep can delete between the directory listing and the
  ``meta.json`` open).

``save(..., extra=...)`` stores a small JSON-serializable sidecar in
``meta.json`` (the serving engine keeps its request queue and layout
fingerprint there); ``read_meta`` returns the whole committed record.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def _dtype_of(name: str) -> np.dtype:
    """Dtype from its recorded string — numpy natives directly,
    extension dtypes (bfloat16, float8_*, ...) via ml_dtypes."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save(state, directory: str, step: int, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  ``extra``: JSON-serializable sidecar
    stored in meta.json.  Returns the committed path."""
    flat, _ = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
            if v is not None}
    return _write(host, directory, step, keep, extra)


def _write(host, directory, step, keep, extra=None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    meta = {"step": step, "leaves": {}}
    if extra is not None:
        meta["extra"] = extra
    used = set()
    for k, v in host.items():
        base = re.sub(r"[^A-Za-z0-9_.|-]", "_", k)
        # sanitization is lossy: two distinct leaf paths can map to the
        # same filename — suffix until unique so the later leaf cannot
        # silently overwrite the earlier one (meta records the exact
        # key→file map either way, and restore reads only through it)
        fn, n = base + ".npy", 0
        while fn in used:
            n += 1
            fn = f"{base}__{n}.npy"
        used.add(fn)
        np.save(os.path.join(tmp, fn), v)
        meta["leaves"][k] = {"file": fn, "shape": list(v.shape),
                             "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory, keep):
    # never retain away the newest committed checkpoint: a concurrent
    # restore may have just selected it, and a directory whose every
    # step can vanish is not a checkpoint directory
    keep = max(int(keep), 1)
    steps = sorted(d for d in os.listdir(directory)
                   if re.fullmatch(r"step_\d{8}", d))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot synchronously, write on a daemon thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, state, step: int, extra: Optional[dict] = None):
        self.wait()
        flat, _ = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
                if v is not None}
        self._thread = threading.Thread(
            target=_write,
            args=(host, self.directory, step, self.keep, extra),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def committed_steps(directory: str):
    """All committed step numbers under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if re.fullmatch(r"step_\d{8}", d))


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def read_meta(directory: str, step: Optional[int] = None):
    """The committed ``meta.json`` record as ``(meta, step)``.  With
    ``step=None`` picks the newest committed step, falling back past
    steps a concurrent retention sweep removed mid-read."""
    candidates = ([step] if step is not None
                  else list(reversed(committed_steps(directory))))
    if not candidates:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    err = None
    for s in candidates:
        try:
            d = os.path.join(directory, f"step_{s:08d}")
            with open(os.path.join(d, "meta.json")) as f:
                return json.load(f), s
        except FileNotFoundError as e:
            err = e
    raise FileNotFoundError(
        f"every committed step under {directory} vanished mid-read "
        f"(candidates {candidates})") from err


def restore(template: Any, directory: str,
            step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of ``template`` (None leaves stay
    None).  ``shardings``: optional matching pytree of NamedShardings —
    the re-shard-on-restore path for elastic rescale.  When ``step`` is
    None, restores the newest committed step, falling back to the
    next-newest if a concurrent keep-k sweep deleted the selected one
    between the directory listing and the read."""
    candidates = ([step] if step is not None
                  else list(reversed(committed_steps(directory))))
    if not candidates:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    err = None
    for s in candidates:
        try:
            return _load(template, directory, s, shardings), s
        except FileNotFoundError as e:
            if step is not None:
                raise
            err = e
    raise FileNotFoundError(
        f"every committed step under {directory} vanished mid-read "
        f"(candidates {candidates})") from err


def _load(template, directory, step, shardings):
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    flat, treedef = _flatten(template)
    shard_flat = (_flatten(shardings)[0] if shardings is not None else {})
    out = {}
    for k, leaf in flat.items():
        if leaf is None:
            out[k] = None
            continue
        info = meta["leaves"][k]
        arr = np.load(os.path.join(d, info["file"]))
        want = _dtype_of(info["dtype"])
        if arr.dtype != want:
            # extension dtypes (bfloat16 &c) come back as raw void
            # records from np.load — reinterpret through the recorded
            # dtype so the bytes mean what they meant at save time
            arr = arr.view(want)
        sh = shard_flat.get(k)
        out[k] = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr)
    leaves = [out[k] for k in flat.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)
