"""Sharded, atomic, async checkpointing with keep-k retention.

Layout:   <dir>/step_<N>/<flat.leaf.path>.npy  +  meta.json
Atomicity: written into ``step_<N>.tmp`` then os.replace()'d — a crash
mid-save never corrupts the latest checkpoint (restore scans only
committed dirs).  ``save_async`` snapshots to host memory synchronously
(device buffers stay consistent) and writes on a daemon thread so the
step loop keeps running.  Restore can re-shard onto a different mesh:
pass target shardings and each leaf is device_put accordingly — the
elastic-rescale path (ft/runtime.py) reuses this.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def save(state, directory: str, step: int, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    flat, _ = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
            if v is not None}
    return _write(host, directory, step, keep)


def _write(host, directory, step, keep):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    meta = {"step": step, "leaves": {}}
    for k, v in host.items():
        fn = re.sub(r"[^A-Za-z0-9_.|-]", "_", k) + ".npy"
        np.save(os.path.join(tmp, fn), v)
        meta["leaves"][k] = {"file": fn, "shape": list(v.shape),
                             "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory, keep):
    steps = sorted(d for d in os.listdir(directory)
                   if re.fullmatch(r"step_\d{8}", d))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot synchronously, write on a daemon thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, state, step: int):
        self.wait()
        flat, _ = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
                if v is not None}
        self._thread = threading.Thread(
            target=_write, args=(host, self.directory, step, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if re.fullmatch(r"step_\d{8}", d)]
    return max(steps) if steps else None


def restore(template: Any, directory: str,
            step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of ``template`` (None leaves stay
    None).  ``shardings``: optional matching pytree of NamedShardings —
    the re-shard-on-restore path for elastic rescale."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    flat, treedef = _flatten(template)
    shard_flat = (_flatten(shardings)[0] if shardings is not None else {})
    out = {}
    for k, leaf in flat.items():
        if leaf is None:
            out[k] = None
            continue
        info = meta["leaves"][k]
        arr = np.load(os.path.join(d, info["file"]))
        sh = shard_flat.get(k)
        out[k] = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr)
    leaves = [out[k] for k in flat.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves), step
