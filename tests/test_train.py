"""Training-stack tests: optimizer behaviour, microbatch-accumulation
equivalence, loss descent, and compressed gradient sync correctness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, batch_at
from repro.configs.base import ShapeConfig
from repro.models.model import build_model
from repro.train.optimizer import AdamW, global_norm
from repro.train.train_step import init_state, make_train_step


def _setup(arch="qwen2-0.5b"):
    cfg = get_arch(arch).smoke()
    m = build_model(cfg)
    opt = AdamW(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    return cfg, m, opt


def test_loss_descends_over_steps(rng):
    cfg, m, opt = _setup()
    step = jax.jit(make_train_step(m, opt))
    state = init_state(m, jax.random.PRNGKey(0), opt)
    shape = ShapeConfig("t", 64, 4, "train")
    dcfg = DataConfig(seed=1)
    # one fixed batch: loss must fall markedly when memorizing
    batch = jax.tree.map(jnp.asarray, batch_at(cfg, shape, dcfg, 0))
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.slow
def test_microbatch_equivalence(rng):
    """k-way grad accumulation == single big batch (same update)."""
    cfg, m, opt = _setup()
    state = init_state(m, jax.random.PRNGKey(0), opt)
    shape = ShapeConfig("t", 32, 4, "train")
    batch = jax.tree.map(jnp.asarray,
                         batch_at(cfg, shape, DataConfig(seed=2), 0))
    s1, m1 = jax.jit(make_train_step(m, opt))(state, batch)
    s2, m2 = jax.jit(make_train_step(m, opt, microbatches=2))(state, batch)
    # loss and global grad norm must agree tightly; params only up to
    # the Adam step size (m/sqrt(v) ≈ ±1 is sign-unstable where the
    # true gradient is ~0, so elementwise equality is ill-posed).
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) \
        < 1e-3 * float(m1["grad_norm"])
    lr = float(m1["lr"])
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        assert np.abs(np.asarray(a) - np.asarray(b)).max() < 2.5 * lr


def test_adamw_lr_schedule():
    opt = AdamW(peak_lr=1.0, warmup_steps=10, total_steps=110)
    lrs = [float(opt.lr(jnp.int32(s))) for s in (0, 9, 10, 60, 109)]
    assert lrs[0] < lrs[1] <= 1.0            # warmup rises
    assert abs(lrs[2] - 1.0) < 0.2           # peak
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]  # cosine decays


def test_grad_clipping():
    opt = AdamW(clip_norm=1e-9)  # everything clipped to ~zero update
    params = {"w": jnp.ones(4)}
    st = opt.init(params)
    p2, _, m = opt.update({"w": jnp.full(4, 100.0)}, st, params)
    assert float(m["grad_norm"]) > 100
    assert np.abs(np.asarray(p2["w"]) - 1.0).max() < 1e-3


def test_compression_error_feedback_unbiased():
    """int8 + error feedback: the *accumulated* compressed stream
    converges to the accumulated true gradient (unbiasedness)."""
    from repro.train.compress import _dequant, _quantize
    rng = np.random.default_rng(0)
    g_true = rng.standard_normal(1000).astype(np.float32)
    err = np.zeros_like(g_true)
    acc_c, acc_t = np.zeros_like(g_true), np.zeros_like(g_true)
    for _ in range(50):
        q, s = _quantize(jnp.asarray(g_true + err))
        deq = np.asarray(_dequant(q, s))
        err = (g_true + err) - deq
        acc_c += deq
        acc_t += g_true
    rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
    assert rel < 0.01, rel


def test_compressed_pmean_matches_plain():
    """Compressed cross-pod mean ≈ plain mean on a 2-'pod' shard_map."""
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (covered by dry-run CI lane)")
    from jax.sharding import PartitionSpec as P
    from repro.train import compress as C
    mesh = jax.make_mesh((2,), ("pod",))
    g = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((2, 64)).astype(np.float32))
    ef = C.EFState(err=jnp.zeros((1, 64), jnp.float32))

    def f(gl, el):
        out, ef2 = C.compressed_pmean(gl, C.EFState(err=el), "pod")
        return out, ef2.err

    got, _ = jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P(None)),
                           out_specs=(P("pod"), P(None)))(g, ef.err)
    want = g.mean(axis=0, keepdims=True)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want)[0],
                               atol=0.02)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
