"""Allocator invariants for all six Ouroboros variants.

Invariants (the paper's correctness criterion §3: write data, read it
back intact):
  A1  granted offsets are unique and in-bounds
  A2  granted regions never overlap (interval check + data tags)
  A3  free→realloc recycles (no leak across cycles)
  A4  over-capacity requests fail with −1, never corrupt state
  A5  data written through one allocation never clobbers another —
      including the virtualized queues' own in-heap segments
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

# 1 MiB heap / 4 KiB chunks: page variants carve ~25 chunks per class at
# init (fixed partition — the paper's fragmentation trade-off), so test
# demand must stay inside one class-share for full-grant assertions.
CFG = HeapConfig(total_bytes=1 << 20, chunk_bytes=1 << 12,
                 min_page_bytes=16)


@pytest.fixture(scope="module", params=VARIANTS)
def ouro(request):
    return Ouroboros(CFG, request.param)


def _alloc(ouro, st, sizes):
    sizes = jnp.asarray(sizes, jnp.int32)
    st, offs = ouro.alloc(st, sizes, jnp.ones(sizes.shape[0], bool))
    return st, np.asarray(offs)


def test_unique_and_inbounds(ouro):
    st = ouro.init()
    sizes = np.tile([16, 64, 256, 512, 1024], 20)
    st, offs = _alloc(ouro, st, sizes)
    good = offs[offs >= 0]
    assert len(good) == len(sizes)
    assert len(np.unique(good)) == len(good)
    assert (good >= 0).all() and (good < CFG.total_words).all()


def test_no_overlap_intervals(ouro):
    st = ouro.init()
    rng = np.random.default_rng(7)
    sizes = rng.choice([16, 32, 128, 512, 2048], 100)
    st, offs = _alloc(ouro, st, sizes)
    ivs = sorted((int(o), int(o) + max(int(s) // 4, 1))
                 for o, s in zip(offs, sizes) if o >= 0)
    for (a, b), (c, _) in zip(ivs, ivs[1:]):
        assert c >= b, f"overlap at {a}:{b} vs {c}"


def test_free_realloc_cycle(ouro):
    st = ouro.init()
    sizes = jnp.full(64, 1024, jnp.int32)
    mask = jnp.ones(64, bool)
    seen_failure = False
    for _ in range(8):  # 8 cycles × 64 KiB-pages in a 256 KiB heap
        st, offs = ouro.alloc(st, sizes, mask)
        offs_np = np.asarray(offs)
        seen_failure |= (offs_np < 0).any()
        st = ouro.free(st, offs, sizes, mask)
    assert not seen_failure, "leak: recycled pages stopped being granted"


def test_exhaustion_fails_clean(ouro):
    st = ouro.init()
    n = 2 * CFG.total_bytes // 4096
    sizes = jnp.full(n, 4096, jnp.int32)
    st, offs_j = ouro.alloc(st, sizes, jnp.ones(n, bool))
    offs = np.asarray(offs_j)
    assert (offs < 0).any()
    good = offs[offs >= 0]
    assert len(np.unique(good)) == len(good)
    # Recovery: free everything; chunk variants additionally need
    # compact() — chunk→class binding is sticky without atomics
    # (DESIGN.md §5b), so the 4 KiB exhaustion bound every chunk.
    st = ouro.free(st, offs_j, sizes, jnp.ones(n, bool))
    st = ouro.compact(st)
    st, offs2 = _alloc(ouro, st, [16] * 8)
    assert (np.asarray(offs2) >= 0).all()


def test_oversize_rejected(ouro):
    st = ouro.init()
    st, offs = _alloc(ouro, st, [CFG.chunk_bytes * 2])
    assert offs[0] == -1


def test_data_integrity_under_churn(ouro):
    st = ouro.init()
    rng = np.random.default_rng(3)
    live = {}
    tagc = 0
    for it in range(5):
        n = 64
        sizes = jnp.asarray(rng.choice([16, 64, 256, 1024], n), jnp.int32)
        st, offs = ouro.alloc(st, sizes, jnp.ones(n, bool))
        tags = jnp.arange(tagc, tagc + n, dtype=jnp.int32)
        tagc += n
        st = ouro.write_pattern(st, offs, sizes, tags)
        for i, o in enumerate(np.asarray(offs)):
            if o >= 0:
                live[int(o)] = (int(sizes[i]), tagc - n + i)
        keys = list(live)
        drop = [keys[i] for i in
                rng.choice(len(keys), len(keys) // 3, replace=False)]
        fo = jnp.asarray(drop + [0] * (n - len(drop)), jnp.int32)
        fs = jnp.asarray([live[k][0] for k in drop] + [0] * (n - len(drop)),
                         jnp.int32)
        fm = jnp.asarray([True] * len(drop) + [False] * (n - len(drop)))
        st = ouro.free(st, fo, fs, fm)
        for k in drop:
            del live[k]
        if live:
            ko = jnp.asarray(list(live), jnp.int32)
            ks = jnp.asarray([live[k][0] for k in live], jnp.int32)
            kt = jnp.asarray([live[k][1] for k in live], jnp.int32)
            ok = np.asarray(ouro.check_pattern(st, ko, ks, kt))
            assert ok.all(), f"data corrupted at iter {it}"


# ---- write_pattern / check_pattern: the paper-§3 integrity check ----------
# (deliberately corrupted offset sets MUST flip the flag — this is what
# the benchmark's data_ok column and the parity harness rely on)

def test_check_pattern_detects_aliased_offsets():
    """Two lanes pointed at the same region: the later write clobbers
    the earlier tag, so the earlier lane's integrity flag must drop."""
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    offs = jnp.asarray([128, 128], jnp.int32)       # deliberate alias
    sizes = jnp.full(2, 64, jnp.int32)
    tags = jnp.asarray([7, 9], jnp.int32)
    st = ouro.write_pattern(st, offs, sizes, tags)
    ok = np.asarray(ouro.check_pattern(st, offs, sizes, tags))
    assert not ok[0], "aliased write must corrupt lane 0's tag"
    assert ok[1], "last writer's own tag is intact"


def test_check_pattern_detects_partial_overlap():
    """Offsets overlapping by a strict sub-range (64 B regions, 32 B
    apart) corrupt exactly the overlapped lane."""
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    offs = jnp.asarray([0, 8, 64], jnp.int32)       # words; 8 < 64/4
    sizes = jnp.full(3, 64, jnp.int32)
    tags = jnp.asarray([1, 2, 3], jnp.int32)
    st = ouro.write_pattern(st, offs, sizes, tags)
    ok = np.asarray(ouro.check_pattern(st, offs, sizes, tags))
    assert list(ok) == [False, True, True]


def test_check_pattern_failed_lanes_report_false():
    """Failed allocations (offset −1) are never 'intact': the flag is
    False and the write is dropped (no heap corruption)."""
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    heap_before = np.asarray(ouro.heap(st))
    offs = jnp.asarray([-1, 256], jnp.int32)
    sizes = jnp.full(2, 64, jnp.int32)
    tags = jnp.asarray([5, 6], jnp.int32)
    st = ouro.write_pattern(st, offs, sizes, tags)
    ok = np.asarray(ouro.check_pattern(st, offs, sizes, tags))
    assert list(ok) == [False, True]
    # the failed lane wrote nothing anywhere
    heap_after = np.asarray(ouro.heap(st))
    touched = np.nonzero(heap_after != heap_before)[0]
    assert touched.min() >= 256 and touched.max() < 256 + 16


def test_check_pattern_clean_on_disjoint_granted(ouro):
    """Control: genuinely disjoint allocator grants all verify True —
    across every variant (the paper's §3 criterion end-to-end).  Lane
    width 64 matches the churn test so transactions reuse its jit
    cache."""
    st = ouro.init()
    sizes = jnp.asarray([16, 64, 256, 1024] * 16, jnp.int32)
    st, offs = ouro.alloc(st, sizes, jnp.ones(64, bool))
    tags = jnp.arange(100, 164, dtype=jnp.int32)
    st = ouro.write_pattern(st, offs, sizes, tags)
    ok = np.asarray(ouro.check_pattern(st, offs, sizes, tags))
    granted = np.asarray(offs) >= 0
    assert ok[granted].all() and granted.any()


def test_masked_lanes_ignored(ouro):
    st = ouro.init()
    sizes = jnp.full(16, 64, jnp.int32)
    mask = jnp.asarray([True, False] * 8)
    st, offs = ouro.alloc(st, sizes, mask)
    offs = np.asarray(offs)
    assert (offs[1::2] == -1).all()
    assert (offs[::2] >= 0).all()
