"""End-to-end behaviour tests: every assigned architecture smokes
(forward + train step on a reduced config, CPU), decode matches the
train-mode forward on one arch per family, and the paged-KV serving
engine round-trips requests through the Ouroboros allocator.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_arch
from repro.models.model import build_model
from repro.paged import kv_cache as KV

B, S = 2, 32


def _batch(cfg, rng, s=S):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)),
                               jnp.int32),
    }
    if cfg.modality == "audio":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, s, cfg.d_model)), jnp.float32)
    if cfg.modality == "vision":
        batch["mm_embeds"] = jnp.asarray(
            rng.standard_normal((B, s, cfg.d_model)) * 0.02, jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    """One forward + one optimizer step on the reduced config: output
    shapes correct, loss finite, gradients flow (params change)."""
    cfg = get_arch(arch).smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    loss, metrics = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.padded_vocab)) < 1.5

    from repro.train.optimizer import AdamW
    from repro.train.train_step import init_state, make_train_step
    opt = AdamW(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(m, opt))
    state = init_state(m, jax.random.PRNGKey(0), opt)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert moved


FAMILY_REPS = ["qwen2-0.5b", "mixtral-8x7b", "mamba2-780m",
               "recurrentgemma-9b", "seamless-m4t-large-v2"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_decode_matches_forward(arch, rng):
    """Paged-KV/stateful decode reproduces the train-mode forward
    logits token-by-token (f32, MoE no-drop capacity)."""
    cfg = get_arch(arch).smoke()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=4.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    T, S0 = 40, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)

    if cfg.is_encdec:
        from repro.models import encdec as ED
        se = jnp.asarray(rng.standard_normal((B, S0, cfg.d_model)),
                         jnp.float32)
        enc = ED.encode(cfg, params, se, "full", jnp.float32)
        logits_full, _ = ED.decode_stack(
            cfg, params, toks, enc, "train",
            ED.EncDecCaches(None, None, None, None), "full", jnp.float32)
    else:
        from repro.models import transformer as TF
        logits_full, _, _ = TF.forward(cfg, params, toks, mode="train",
                                       dtype=jnp.float32)

    caches = m.make_decode_caches(B, max_seq=T, kv_dtype=jnp.float32)
    pps = -(-T // KV.PAGE_SIZE)
    pt = (jnp.arange(B)[:, None] * pps
          + jnp.arange(pps)[None, :]).astype(jnp.int32)
    if cfg.is_encdec:
        caches = caches._replace(self_kv=caches.self_kv._replace(
            page_table=pt))
    elif caches.kv is not None:
        caches = caches._replace(kv=caches.kv._replace(page_table=pt))

    batch_pre = {"tokens": toks[:, :S0]}
    if cfg.is_encdec:
        batch_pre["src_embeds"] = se
    lp, caches = m.prefill(params, batch_pre, caches, dtype=jnp.float32)
    scale = float(np.abs(np.asarray(logits_full)).max())
    errs = [float(np.abs(lp - logits_full[:, S0 - 1]).max())]
    for t in range(S0, T):
        ld, caches = m.decode_step(params, toks[:, t:t + 1], caches,
                                   dtype=jnp.float32)
        errs.append(float(np.abs(ld - logits_full[:, t]).max()))
    assert max(errs) < 0.01 * max(scale, 1.0), errs


def test_engine_roundtrip(rng):
    from repro.serve.engine import ServingEngine
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                        kv_dtype=jnp.float32)
    for _ in range(5):
        eng.submit(rng.integers(2, cfg.vocab_size,
                                int(rng.integers(4, 30))),
                   max_new_tokens=6)
    done = eng.run_until_done(200)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    assert eng.stats["alloc_failures"] == 0
    assert eng.stats["frees"] == eng.stats["allocs"]


def test_engine_validates_allocator_knobs():
    """A typo like alloc_backend="palas" must fail at construction
    with the menu of choices — never silently behave like another
    configuration (it previously surfaced, if at all, only from deep
    inside allocator setup)."""
    from repro.serve.engine import ServingEngine
    with pytest.raises(ValueError, match="alloc_backend.*palas"):
        ServingEngine(None, None, alloc_backend="palas")
    with pytest.raises(ValueError, match="alloc_lowering.*bocked"):
        ServingEngine(None, None, alloc_lowering="bocked")


def test_engine_surfaces_active_lowering(rng):
    """engine.stats reports the allocator backend and the RESOLVED
    kernel lowering actually in use (whole|blocked for pallas, none
    for jnp), so operators can tell which compiled story served a
    request stream."""
    from repro.serve.engine import ServingEngine
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32, alloc_backend="pallas",
                        alloc_lowering="blocked")
    assert eng.stats["alloc_backend"] == "pallas"
    assert eng.stats["alloc_lowering"] == "blocked"
    eng.submit(rng.integers(2, cfg.vocab_size, 6), max_new_tokens=3)
    done = eng.run_until_done(50)
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    assert eng.stats["alloc_failures"] == 0
    assert eng.stats["frees"] == eng.stats["allocs"] > 0

    eng2 = ServingEngine(m, params, max_batch=2, max_seq=64,
                         kv_dtype=jnp.float32, alloc_backend="jnp")
    assert eng2.stats["alloc_lowering"] == "none"


def test_engine_roundtrip_pallas_alloc_backend(rng):
    """The engine's bulk page grants/releases through the fused
    single-kernel arena transactions (alloc_backend="pallas") behave
    identically to the jnp oracle path: same grants, no failures, all
    pages returned.  (Bit-level backend parity is test_alloc_txn_parity;
    this pins the serving wiring end to end.)"""
    from repro.serve.engine import ServingEngine
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32, alloc_backend="pallas")
    assert eng.stats["arena_mem_words"] > 0
    for _ in range(3):
        eng.submit(rng.integers(2, cfg.vocab_size,
                                int(rng.integers(4, 20))),
                   max_new_tokens=4)
    done = eng.run_until_done(100)
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.stats["alloc_failures"] == 0
    assert eng.stats["frees"] == eng.stats["allocs"] > 0


def test_engine_sharded_allocator(rng):
    """num_shards>1: the engine's KV allocator becomes the sharded
    multi-arena (core/shards.py) — each sequence slot homes on
    slot % num_shards — and stats expose per-shard live-page
    occupancy that returns to zero when every request retires."""
    from repro.serve.engine import ServingEngine
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                        kv_dtype=jnp.float32, num_shards=2)
    assert eng.stats["num_shards"] == 2
    assert len(eng.stats["shard_pages_live"]) == 2
    for _ in range(4):
        eng.submit(rng.integers(2, cfg.vocab_size,
                                int(rng.integers(4, 24))),
                   max_new_tokens=4)
    eng.step()  # admit: slots 0..2 prefill → shards 0 and 1 populated
    live = eng.stats["shard_pages_live"]
    assert sum(live) == eng.stats["allocs"] - eng.stats["frees"]
    assert all(x > 0 for x in live), \
        "slot % num_shards routing left a shard empty mid-flight"
    done = eng.run_until_done(200)
    assert len(done) == 4
    assert eng.stats["alloc_failures"] == 0
    assert eng.stats["frees"] == eng.stats["allocs"] > 0
    assert eng.stats["shard_pages_live"] == [0, 0], \
        "per-shard occupancy must drain with the requests"


def test_engine_validates_num_shards():
    from repro.serve.engine import ServingEngine
    with pytest.raises(ValueError, match="num_shards"):
        ServingEngine(None, None, num_shards=0)


def test_engine_greedy_matches_batch_decode(rng):
    """Engine output == straight prefill+decode for the same prompt."""
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = rng.integers(2, cfg.vocab_size, 12)

    from repro.serve.engine import ServingEngine
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    eng.submit(prompt, max_new_tokens=5)
    done = eng.run_until_done(50)
    got = done[0].out_tokens

    # reference with IDENTICAL batch shape (padded row) and dtype so
    # the computation is bit-identical and argmax ties cannot flip
    caches = m.make_decode_caches(2, max_seq=64, kv_dtype=jnp.float32)
    pps = -(-64 // KV.PAGE_SIZE)
    pt = jnp.full((2, pps), -1, jnp.int32).at[0].set(jnp.arange(pps))
    caches = caches._replace(kv=caches.kv._replace(page_table=pt))
    toks = np.zeros((2, len(prompt)), np.int32)
    toks[0] = prompt
    lp, caches = m.prefill(params, {"tokens": jnp.asarray(toks)}, caches,
                           dtype=jnp.float32)
    want = [int(np.argmax(np.asarray(lp[0])))]
    for _ in range(4):
        step_toks = jnp.asarray([[want[-1]], [0]], jnp.int32)
        ld, caches = m.decode_step(params, step_toks, caches,
                                   dtype=jnp.float32)
        want.append(int(np.argmax(np.asarray(ld[0]))))
    assert got == want
