"""Crash-safe serving tests (DESIGN.md §12).

The contract under test: ``ServingEngine.snapshot()/restore()``
captures the COMPLETE serving state — arena word image + control block
(all shards), KV page heaps + page tables + seq_lens, the mega-step
carry + host mirrors, the request queue, and the stats block — such
that a restored engine (a) holds word-for-word identical arena/KV
state and (b) resumes decoding token-identically, across allocator
backends, lowerings, and shard counts.  A snapshot from a different
``ArenaLayout`` or engine geometry must be rejected loudly (the
fingerprint is pinned to ``tests/golden/``), never silently
misinterpreted.
"""
import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.model import build_model

pytestmark = pytest.mark.ft

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _engine(tiny_model, **kw):
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    kw.setdefault("kv_dtype", jnp.float32)
    kw.setdefault("compute_dtype", jnp.float32)
    return ServingEngine(m, params, max_batch=3, max_seq=96, **kw)


def _submit(eng, cfg, n=4, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(rng.integers(2, cfg.vocab_size,
                                int(rng.integers(4, 30))),
                   max_new_tokens=max_new)


def _toks(reqs):
    return {r.uid: list(r.out_tokens) for r in reqs}


# ---- word-for-word round-trip across the backend matrix -------------------

@pytest.mark.parametrize("backend,lowering,shards", [
    ("jnp", "auto", 1),
    ("jnp", "auto", 4),
    ("pallas", "whole", 1),
    ("pallas", "blocked", 1),
    ("pallas", "whole", 4),
    ("pallas", "blocked", 4),
])
def test_snapshot_roundtrip_word_for_word(tiny_model, backend,
                                          lowering, shards):
    """Snapshot mid-decode, restore into a FRESH engine: every arena
    word, control word, KV heap word, and page-table entry must match
    the source engine exactly — and the restored engine must finish
    the in-flight streams token-identically to an uninterrupted run."""
    cfg = tiny_model[0]
    kw = dict(alloc_backend=backend, alloc_lowering=lowering,
              num_shards=shards)

    ref = _engine(tiny_model, **kw)
    _submit(ref, cfg)
    want = _toks(ref.run_until_done(300))

    src = _engine(tiny_model, **kw)
    _submit(src, cfg)
    early = []
    for _ in range(3):
        early.extend(src.step())
    snap = src.snapshot()

    dst = _engine(tiny_model, **kw)
    assert dst.restore(snap) is None
    np.testing.assert_array_equal(np.asarray(src.alloc_state.mem),
                                  np.asarray(dst.alloc_state.mem))
    np.testing.assert_array_equal(np.asarray(src.alloc_state.ctl),
                                  np.asarray(dst.alloc_state.ctl))
    for a, b in zip(jax.tree.leaves(src.caches),
                    jax.tree.leaves(dst.caches)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    got = _toks(early + dst.run_until_done(300))
    assert got == want
    assert dst.stats["frees"] == dst.stats["allocs"]


def test_snapshot_restores_across_backend_and_lowering(tiny_model):
    """Backend/lowering are deliberately NOT in the fingerprint:
    transactions are bit-identical across them, so a snapshot taken on
    the jnp reference path restores onto fused Pallas kernels (and the
    blocked lowering) mid-stream with identical output."""
    cfg = tiny_model[0]
    ref = _engine(tiny_model, alloc_backend="jnp")
    _submit(ref, cfg)
    want = _toks(ref.run_until_done(300))

    src = _engine(tiny_model, alloc_backend="jnp")
    _submit(src, cfg)
    early = []
    for _ in range(3):
        early.extend(src.step())
    snap = src.snapshot()

    dst = _engine(tiny_model, alloc_backend="pallas",
                  alloc_lowering="blocked")
    dst.restore(snap)
    assert _toks(early + dst.run_until_done(300)) == want


# ---- kill-mid-decode → restore → token parity (tmp_path = "disk") ---------

@pytest.mark.parametrize("mega", [False, True])
def test_kill_mid_decode_restores_token_identically(tiny_model, mega,
                                                    tmp_path):
    """The crash path: decode a few ticks, snapshot to a committed
    on-disk checkpoint, DISCARD the engine (the "kill"), restore in a
    fresh process-equivalent engine, finish — killed-run + resumed-run
    streams concatenate to exactly the uninterrupted run's streams,
    for both decode loops."""
    cfg = tiny_model[0]
    ref = _engine(tiny_model, mega_step=mega)
    _submit(ref, cfg)
    want = _toks(ref.run_until_done(300))

    eng = _engine(tiny_model, mega_step=mega)
    _submit(eng, cfg)
    early = []
    for _ in range(4):
        early.extend(eng.step())
    eng.snapshot(directory=str(tmp_path))
    del eng  # the crash

    resumed = _engine(tiny_model, mega_step=mega)
    step = resumed.restore(str(tmp_path))
    assert step == 4
    got = _toks(early + resumed.run_until_done(300))
    assert got == want
    assert resumed.stats["frees"] == resumed.stats["allocs"]


# ---- layout-validation contract (golden pin + loud rejection) -------------

def test_snapshot_fingerprint_matches_golden(tiny_model):
    """The fingerprint of the canonical test engine is pinned to
    tests/golden/ — any change to the arena layout rendering, the
    allocator geometry, or the fingerprinted engine fields shows up as
    a reviewable golden diff (and invalidates old snapshots loudly)."""
    eng = _engine(tiny_model)
    got = json.dumps(eng.snapshot_fingerprint(), indent=2,
                     sort_keys=True) + "\n"
    want = (GOLDEN / "serve_snapshot_fingerprint.txt").read_text()
    assert got == want, (
        "serving snapshot fingerprint drifted from "
        "tests/golden/serve_snapshot_fingerprint.txt — if the layout "
        "change is intentional, re-render the golden and note that "
        "existing snapshots are invalidated")


def test_restore_rejects_mismatched_layout(tiny_model):
    """A snapshot whose fingerprint differs — different shard count,
    or a tampered arena-layout rendering — is rejected with a
    ValueError naming the differing fields BEFORE any engine state is
    mutated."""
    cfg = tiny_model[0]
    src = _engine(tiny_model, num_shards=1)
    _submit(src, cfg)
    src.step()
    snap = src.snapshot()

    other = _engine(tiny_model, num_shards=4)
    ctl_before = np.asarray(other.alloc_state.ctl).copy()
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        other.restore(snap)
    np.testing.assert_array_equal(np.asarray(other.alloc_state.ctl),
                                  ctl_before)

    tampered = {"tree": snap["tree"],
                "meta": json.loads(json.dumps(snap["meta"]))}
    tampered["meta"]["fingerprint"]["arena_layout"] += " (tampered)"
    dst = _engine(tiny_model, num_shards=1)
    with pytest.raises(ValueError, match="arena_layout"):
        dst.restore(tampered)


def test_restore_rejects_non_snapshot_checkpoint(tiny_model, tmp_path):
    """A plain training checkpoint (no fingerprint sidecar) under the
    snapshot dir is refused, not misread."""
    from repro.ckpt import checkpoint as CK
    CK.save({"w": jnp.zeros(4)}, str(tmp_path), step=1)
    eng = _engine(tiny_model)
    with pytest.raises(ValueError, match="not a serving-engine"):
        eng.restore(str(tmp_path))


# ---- eviction degradation surfaces in the snapshot state ------------------

def test_snapshot_carries_queue_and_eviction_stats(tiny_model):
    """The JSON sidecar round-trips the waiting queue, in-flight
    requests, and counters — including ``evictions`` — so a restored
    engine's stats are continuous with the killed run's."""
    cfg = tiny_model[0]
    eng = _engine(tiny_model)
    _submit(eng, cfg, n=6)  # 6 requests > 3 slots → some stay queued
    eng.step()
    eng.stats["evictions"] = 2  # pretend the killed run degraded
    snap = eng.snapshot()

    dst = _engine(tiny_model)
    dst.restore(snap)
    assert dst.stats["evictions"] == 2
    assert len(dst.waiting) == len(eng.waiting)
    assert [r and r.uid for r in dst.slot_req] == \
        [r and r.uid for r in eng.slot_req]
    got = _toks(dst.run_until_done(300))
    assert sorted(got) == list(range(1, 7))


# ---- observability state rides in the snapshot (DESIGN.md §14) ------------

@pytest.mark.obs
def test_snapshot_roundtrips_telemetry_and_metrics(tiny_model):
    """The in-kernel telemetry words are ordinary ctl words, so a
    restored engine drains word-identical accumulators — and a metrics
    registry publishing from the restored engine reports the same
    counter totals as one publishing from the source.  The telemetry
    is non-trivial by the time we snapshot (allocs have happened), so
    this is not an all-zeros comparison."""
    cfg = tiny_model[0]
    src = _engine(tiny_model)
    _submit(src, cfg)
    for _ in range(3):
        src.step()
    tele_src = src.drain_telemetry()
    assert int(np.asarray(tele_src["t_alloc"]).sum()) > 0
    snap = src.snapshot()

    dst = _engine(tiny_model)
    dst.restore(snap)
    tele_dst = dst.drain_telemetry()
    assert sorted(tele_src) == sorted(tele_dst)
    for field in tele_src:
        np.testing.assert_array_equal(
            np.asarray(tele_src[field]), np.asarray(tele_dst[field]),
            err_msg=f"telemetry {field} not restored word-for-word")

    from repro.obs.metrics import MetricsRegistry, validate_exposition
    text_src = src.publish_metrics(MetricsRegistry()).to_prometheus()
    text_dst = dst.publish_metrics(MetricsRegistry()).to_prometheus()
    validate_exposition(text_src)

    def totals(text, keep):
        return sorted(l for l in text.splitlines()
                      if l.startswith(keep))
    for fam in ("repro_alloc_granted_total", "repro_free_total",
                "repro_alloc_failed_total", "repro_engine_allocs_total",
                "repro_engine_steps_total"):
        assert totals(text_src, fam) == totals(text_dst, fam), (
            f"{fam} diverged across snapshot/restore")

    # the restored stream continues token-identically with telemetry
    # still accumulating monotonically
    dst.run_until_done(300)
    tele_after = dst.drain_telemetry()
    assert int(np.asarray(tele_after["t_alloc"]).sum()) >= \
        int(np.asarray(tele_dst["t_alloc"]).sum())
