"""Paged KV cache unit tests: append/prefill writes, paged attention vs
dense flash, int8 quantization error bounds, windowed masking."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import flash_attention
from repro.paged import kv_cache as KV


def _layer(rng, NP=16, page=8, Hkv=2, D=32, dtype=jnp.float32):
    quant = dtype == jnp.int8
    return KV.KVLayer(
        k=jnp.zeros((NP, page, Hkv, D), dtype),
        v=jnp.zeros((NP, page, Hkv, D), dtype),
        k_scale=jnp.zeros((NP, page, Hkv), jnp.float32) if quant else None,
        v_scale=jnp.zeros((NP, page, Hkv), jnp.float32) if quant else None)


def _pt(B, P):
    return (jnp.arange(B)[:, None] * P + jnp.arange(P)[None, :]).astype(
        jnp.int32)


def test_prefill_then_append_then_attend(rng):
    B, S, Hq, Hkv, D, page = 2, 24, 4, 2, 32, 8
    P = 4
    lay = _layer(rng, NP=B * P, page=page, Hkv=Hkv, D=D)
    pt = _pt(B, P)
    k = jnp.asarray(rng.standard_normal((B, S + 1, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S + 1, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)

    lay = KV.prefill_write1(lay, pt, k[:, :S], v[:, :S])
    lay = KV.append1(lay, pt, jnp.full(B, S, jnp.int32),
                     k[:, S:], v[:, S:])
    got = KV.paged_attend1(lay, pt, jnp.full(B, S + 1, jnp.int32), q,
                           page_block=2)
    want = flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-2, rtol=2e-2)


def test_paged_attend_respects_kv_len(rng):
    B, Hq, Hkv, D, page, P = 1, 2, 1, 16, 4, 4
    lay = _layer(rng, NP=P, page=page, Hkv=Hkv, D=D)
    pt = _pt(B, P)
    T = P * page
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    lay = KV.prefill_write1(lay, pt, k, v)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    for kv_len in (1, 5, 12):
        got = KV.paged_attend1(lay, pt, jnp.asarray([kv_len]), q)
        want = flash_attention(q, k[:, :kv_len], v[:, :kv_len],
                               causal=False)
        np.testing.assert_allclose(got, want, atol=2e-2, rtol=2e-2)


def test_paged_attend_window(rng):
    B, Hq, Hkv, D, page, P = 1, 2, 1, 16, 4, 8
    lay = _layer(rng, NP=P, page=page, Hkv=Hkv, D=D)
    pt = _pt(B, P)
    T = P * page
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    lay = KV.prefill_write1(lay, pt, k, v)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    kv_len, win = 30, 8
    got = KV.paged_attend1(lay, pt, jnp.asarray([kv_len]), q, window=win)
    want = flash_attention(q, k[:, kv_len - win:kv_len],
                           v[:, kv_len - win:kv_len], causal=False)
    np.testing.assert_allclose(got, want, atol=2e-2, rtol=2e-2)


def test_int8_kv_quantization_error(rng):
    B, S, Hq, Hkv, D, page = 2, 32, 4, 2, 64, 8
    P = S // page
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    pt = _pt(B, P)

    lay8 = _layer(rng, NP=B * P, page=page, Hkv=Hkv, D=D, dtype=jnp.int8)
    lay8 = KV.prefill_write1(lay8, pt, k, v)
    got = KV.paged_attend1(lay8, pt, jnp.full(B, S, jnp.int32), q)
    want = flash_attention(q, k, v, causal=False)
    # int8 per-(slot, head) scales: ~1% relative error budget
    rel = np.abs(np.asarray(got) - np.asarray(want)).max() / \
        np.abs(np.asarray(want)).max()
    assert rel < 0.05, rel


def test_holes_are_dropped(rng):
    """Unmapped pages (-1) neither write nor contribute to attention."""
    B, Hkv, D, page, P = 1, 1, 16, 4, 4
    lay = _layer(rng, NP=P, page=page, Hkv=Hkv, D=D)
    pt = jnp.asarray([[0, -1, 1, -1]], jnp.int32)
    S = P * page
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    before = np.asarray(lay.k).copy()
    lay = KV.prefill_write1(lay, pt, k, v)
    after = np.asarray(lay.k)
    # pages 2, 3 of the heap were never mapped → untouched
    np.testing.assert_array_equal(after[2:], before[2:])

    q = jnp.asarray(rng.standard_normal((B, 1, 2, D)), jnp.float32)
    out = KV.paged_attend1(lay, pt, jnp.asarray([S]), q)
    # equivalent dense attention over the mapped positions only
    sel = np.r_[0:4, 8:12]
    want = flash_attention(q, k[:, sel], v[:, sel], causal=False)
    np.testing.assert_allclose(out, want, atol=2e-2, rtol=2e-2)


def test_kv_allocator_page_space(rng):
    """The Ouroboros-backed page-id allocator grants exactly the page
    space and recycles freed ids."""
    ouro, wpp, physical = KV.make_kv_allocator(num_pages=64)
    st = ouro.init()
    sizes = jnp.full(64, 256, jnp.int32)
    st, offs = ouro.alloc(st, sizes, jnp.ones(64, bool))
    ids = np.asarray(offs) // wpp
    good = ids[np.asarray(offs) >= 0]
    assert len(np.unique(good)) == len(good)
    assert len(good) == 64
    # every granted id addresses the physical page array
    assert (good < physical).all()
    st = ouro.free(st, offs, sizes, jnp.ones(64, bool))
    st, offs2 = ouro.alloc(st, sizes, jnp.ones(64, bool))
    assert (np.asarray(offs2) >= 0).sum() >= (np.asarray(offs) >= 0).sum()


@pytest.mark.slow
def test_ring_page_table_window(rng):
    """Ring tables: a window-bounded table serves an unbounded sequence
    (slot = page mod P); attention over the ring equals dense attention
    over the window at every step."""
    B, Hq, Hkv, D, page = 1, 2, 1, 16, 8
    window = 16
    P = window // page + 2          # 4 slots — sequence runs to 6 pages
    T = 48
    lay = _layer(rng, NP=P, page=page, Hkv=Hkv, D=D)
    pt = _pt(B, P)                  # all P physical pages mapped
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)

    for t in range(T):
        lay = KV.append1(lay, pt, jnp.asarray([t]), k[:, t:t + 1],
                         v[:, t:t + 1], ring=True)
        kv_len = t + 1
        got = KV.paged_attend1(lay, pt, jnp.asarray([kv_len]), q,
                               window=window, ring=True)
        lo = max(0, kv_len - window)
        want = flash_attention(q, k[:, lo:kv_len], v[:, lo:kv_len],
                               causal=False)
        np.testing.assert_allclose(got, want, atol=2e-2, rtol=2e-2,
                                   err_msg=f"step {t}")


def test_dense_prefill_fast_path_matches_scatter(rng):
    """Canonical-layout prefill (reshape path) == scatter path."""
    B, S, Hkv, D, page = 2, 24, 2, 16, 8
    P = 4
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    pt = _pt(B, P)  # canonical: id = b·P + j
    lay = _layer(rng, NP=B * P, page=page, Hkv=Hkv, D=D)
    a = KV.prefill_write1(lay, pt, k, v)
    KV.set_dense_prefill(True)
    try:
        b = KV.prefill_write1(lay, pt, k, v)
    finally:
        KV.set_dense_prefill(False)
    np.testing.assert_array_equal(np.asarray(a.k), np.asarray(b.k))
    np.testing.assert_array_equal(np.asarray(a.v), np.asarray(b.v))
