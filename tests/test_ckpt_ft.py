"""Checkpoint/restart + fault-tolerance runtime tests."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as CK
from repro.configs import get_arch
from repro.ft.runtime import PreemptionGuard, StepMonitor
from repro.models.model import build_model
from repro.train.optimizer import AdamW
from repro.train.train_step import init_state


def _state():
    m = build_model(get_arch("qwen2-0.5b").smoke())
    opt = AdamW()
    return m, init_state(m, jax.random.PRNGKey(0), opt)


def test_roundtrip(tmp_path):
    m, state = _state()
    CK.save(state, str(tmp_path), step=7)
    restored, step = CK.restore(state, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_retention(tmp_path):
    m, state = _state()
    for s in (1, 2, 3, 4, 5):
        CK.save(state, str(tmp_path), step=s, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    assert CK.latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    m, state = _state()
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(state, 1)
    ck.save(state, 2)  # waits for previous write internally
    ck.wait()
    assert CK.latest_step(str(tmp_path)) == 2
    restored, _ = CK.restore(state, str(tmp_path))
    assert len(jax.tree.leaves(restored)) == len(jax.tree.leaves(state))


def test_atomicity_partial_write_ignored(tmp_path):
    m, state = _state()
    CK.save(state, str(tmp_path), step=1)
    # simulate a crash mid-save: stray .tmp dir must not be visible
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert CK.latest_step(str(tmp_path)) == 1
    restored, step = CK.restore(state, str(tmp_path))
    assert step == 1


def test_restore_resume_training(tmp_path):
    """Train 3 steps, checkpoint, train 2 more; restart from ckpt and
    replay — identical params (deterministic pipeline by construction)."""
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataConfig, batch_at
    from repro.train.train_step import make_train_step
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    opt = AdamW(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    step_fn = jax.jit(make_train_step(m, opt))
    shape = ShapeConfig("t", 32, 2, "train")
    dcfg = DataConfig(seed=3)

    state = init_state(m, jax.random.PRNGKey(0), opt)
    for s in range(3):
        state, _ = step_fn(state, jax.tree.map(
            jnp.asarray, batch_at(cfg, shape, dcfg, s)))
    CK.save(state, str(tmp_path), step=3)
    cont = state
    for s in range(3, 5):
        cont, _ = step_fn(cont, jax.tree.map(
            jnp.asarray, batch_at(cfg, shape, dcfg, s)))

    resumed, start = CK.restore(state, str(tmp_path))
    assert start == 3
    for s in range(start, 5):
        resumed, _ = step_fn(resumed, jax.tree.map(
            jnp.asarray, batch_at(cfg, shape, dcfg, s)))
    for a, b in zip(jax.tree.leaves(cont.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_colliding_leaf_paths_roundtrip(tmp_path):
    """Regression: sanitization (``[^A-Za-z0-9_.|-] → _``) is lossy,
    so distinct leaf paths like ``a/b`` and ``a?b`` map to the same
    filename — the later leaf used to silently overwrite the earlier
    one and restore returned the wrong tensor for BOTH keys."""
    state = {"a/b": jnp.arange(4), "a?b": jnp.arange(4) + 100,
             "a_b": jnp.arange(4) + 200}
    CK.save(state, str(tmp_path), step=1)
    restored, _ = CK.restore(state, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(restored["a/b"]),
                                  np.arange(4))
    np.testing.assert_array_equal(np.asarray(restored["a?b"]),
                                  np.arange(4) + 100)
    np.testing.assert_array_equal(np.asarray(restored["a_b"]),
                                  np.arange(4) + 200)
    # the key→file map in meta.json is exact and collision-free
    meta, _ = CK.read_meta(str(tmp_path))
    files = [v["file"] for v in meta["leaves"].values()]
    assert len(files) == len(set(files)) == 3


def test_restore_falls_back_when_step_vanishes(tmp_path):
    """Regression for the restore/retention race: the newest committed
    step can be deleted between the directory listing and the read
    (daemon-thread keep-k sweep) — restore must fall back to the
    next-newest committed step instead of crashing."""
    state = {"w": jnp.arange(8)}
    CK.save(state, str(tmp_path), step=1)
    CK.save(state, str(tmp_path), step=2)
    # simulate the race: step_2 committed (listed) but swept before
    # its meta.json is opened
    import shutil
    shutil.rmtree(tmp_path / "step_00000002")
    restored, step = CK.restore(state, str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8))
    # an EXPLICIT step request still fails loudly
    with pytest.raises(FileNotFoundError):
        CK.restore(state, str(tmp_path), step=2)


def test_retention_never_deletes_newest(tmp_path):
    """Even ``keep=0`` must keep the newest committed checkpoint — a
    directory whose every step can vanish would turn the fallback
    above into 'no checkpoints at all'."""
    state = {"w": jnp.arange(2)}
    for s in (1, 2, 3):
        CK.save(state, str(tmp_path), step=s, keep=0)
    assert CK.committed_steps(str(tmp_path)) == [3]


def test_bfloat16_roundtrips_bit_exact(tmp_path):
    """Extension dtypes come back from np.load as void records; the
    recorded-dtype reinterpretation in restore must hand back the
    exact bf16 bits (the serving KV heap defaults to bf16)."""
    x = jnp.asarray(np.linspace(-3, 3, 64, dtype=np.float32),
                    jnp.bfloat16)
    CK.save({"kv": x}, str(tmp_path), step=1)
    restored, _ = CK.restore({"kv": x}, str(tmp_path))
    assert restored["kv"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["kv"]).view(np.uint16),
        np.asarray(x).view(np.uint16))


def test_async_extra_sidecar_roundtrips(tmp_path):
    """``extra=`` rides meta.json through the async writer — the
    serving engine keeps its request queue + layout fingerprint
    there."""
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save({"w": jnp.arange(3)}, 1, extra={"queue": [1, 2], "k": "v"})
    ck.wait()
    meta, step = CK.read_meta(str(tmp_path))
    assert step == 1
    assert meta["extra"] == {"queue": [1, 2], "k": "v"}


def test_step_monitor_stop_without_start():
    """Regression: ``stop()`` with no matching ``start()`` used to
    crash with a bare TypeError from ``None`` arithmetic."""
    mon = StepMonitor()
    with pytest.raises(RuntimeError, match="without a matching"):
        mon.stop()


def test_step_monitor_first_post_warmup_step_flaggable():
    """Regression: the EWMA used to be seeded from the first
    post-warmup measurement itself, so that step could never be
    flagged.  Seeded from the warmup history, a 10× outlier right
    after warmup IS a straggler."""
    mon = StepMonitor(alpha=0.5, threshold=1.5, warmup=2)
    for dt in (0.1, 0.1):  # warmup steps
        mon.start()
        mon._t0 -= dt
        assert not mon.stop()["straggler"]
    mon.start()
    mon._t0 -= 1.0  # first judged step: 10× the warmup median
    assert mon.stop()["straggler"]


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(alpha=0.5, threshold=1.5, warmup=0)
    for dt in (0.1, 0.1, 0.1):
        mon.start()
        mon._t0 -= dt  # fake elapsed
        assert not mon.stop()["straggler"]
    mon.start()
    mon._t0 -= 1.0
    assert mon.stop()["straggler"]


def test_step_monitor_fleet_report():
    mon = StepMonitor(threshold=1.5)
    times = np.array([1.0, 1.1, 0.9, 5.0, 1.0])
    flags = mon.fleet_report(times)
    assert list(flags) == [False, False, False, True, False]


def test_preemption_guard_sets_flag():
    import signal
    g = PreemptionGuard(signals=(signal.SIGUSR1,))
    assert not g.should_stop
    os.kill(os.getpid(), signal.SIGUSR1)
    time.sleep(0.05)
    assert g.should_stop
    g.restore()


def test_elastic_rescale_host_mesh(tmp_path):
    """Save on one 'mesh', restore re-sharded onto another (1-device
    host meshes here; the multi-device path is the same device_put)."""
    from repro.ft.runtime import elastic_rescale
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import ShardingRules
    from repro.train.train_step import abstract_state, state_logical_axes
    m, state = _state()
    opt = AdamW()
    mesh = make_host_mesh()
    rules = ShardingRules.for_mesh(mesh)
    moved = elastic_rescale(state, rules, rules,
                            state_logical_axes(m),
                            abstract_state(m, opt))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
