"""Documentation lockdown: public-API doctests + DESIGN.md drift.

Two failure modes this file exists to catch:

1. **Dead examples** — the docstring examples on the public API
   surface (``Ouroboros``, ``Arena``/``ArenaLayout``, ``ShardedArena``
   and friends, ``transactions.alloc/free``,
   ``kv_cache.make_kv_allocator``) are executable doctests; this
   suite runs them, so a signature or behaviour change that breaks an
   example fails CI (the docs job also runs them via
   ``pytest --doctest-modules``).

2. **Doc drift** — DESIGN.md §7–§9 embed offset/blocking tables that
   are RENDERED from the live layout (``ArenaLayout.describe()`` /
   ``ShardLayout.describe()`` / ``Region.blocking``).  test_heap.py
   pins §7; the checks here extend the same mechanism to §8's
   region-blocking table and §9's sharded tables, so none of the
   three sections can silently diverge from the code.
"""
import doctest
import importlib
import pathlib
import re

import pytest

from repro.core import HeapConfig, arena, shards

DOC = pathlib.Path(__file__).resolve().parent.parent / "DESIGN.md"
CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)

# The documented public API surface.  Every module here must carry at
# least one runnable example — an empty doctest run means the usage
# examples were deleted, which is itself a docs regression.
DOCTEST_MODULES = (
    "repro.core.ouroboros",
    "repro.core.arena",
    "repro.core.defrag",
    "repro.core.shards",
    "repro.core.transactions",
    "repro.paged.kv_cache",
)


@pytest.mark.parametrize("modname", DOCTEST_MODULES)
def test_public_api_doctests(modname):
    mod = importlib.import_module(modname)
    res = doctest.testmod(mod, verbose=False)
    assert res.attempted > 0, (
        f"{modname} lost its runnable usage examples (no doctests "
        f"collected)")
    assert res.failed == 0, (
        f"{modname}: {res.failed}/{res.attempted} doctest examples "
        f"failed — run `pytest --doctest-modules src/{modname.replace('.', '/')}.py` "
        f"for details")


# ---- DESIGN.md §8: the region-blocking table ------------------------------

def test_design_s8_blocking_table_matches_live_policies():
    """Every (region, blocking) pair in the live layouts must appear
    on the §8 table row for that blocking class — so changing a
    ``Region.blocking`` without updating DESIGN.md §8 fails here."""
    doc = DOC.read_text()
    sec = doc.split("## §8")[1].split("\n## §")[0]
    rows = {}
    for m in re.finditer(r"\| `(row|resident|hbm|untouched)`[^\n]*", sec):
        rows[m.group(1)] = m.group(0)
    live = {}
    for kind in arena.KINDS:
        for family in arena.QUEUE_FAMILIES:
            for r in arena.layout(CFG, kind, family).regions:
                live.setdefault(r.blocking, set()).add(r.name)
    assert set(live) <= set(rows), (
        f"DESIGN.md §8 table lost rows: {set(live) - set(rows)}")
    for blocking, names in live.items():
        for nm in sorted(names):
            assert f"`{nm}`" in rows[blocking], (
                f"DESIGN.md §8 drifted: region {nm!r} is "
                f"{blocking!r}-blocked in the live layout but absent "
                f"from that table row")


# ---- DESIGN.md §9: the sharded layout tables ------------------------------

def test_design_s9_shard_tables_match_live_layout():
    """§9's example tables are ``ShardLayout.describe()`` renderings;
    re-render and require the header/offset lines verbatim, exactly as
    test_heap.py pins §7 to ``ArenaLayout.describe()``."""
    doc = DOC.read_text()
    for kind, family in (("page", "ring"), ("chunk", "vl")):
        desc = shards.layout(CFG, 4, kind, family).describe()
        lines = [ln for ln in desc.splitlines()
                 if "mem[" in ln or ln.startswith("sharded arena(")
                 or "global heap offset" in ln]
        assert lines, "describe() rendering changed shape"
        for ln in lines:
            assert ln in doc, (
                f"DESIGN.md §9 drifted from the live sharded layout: "
                f"{ln!r}")


def test_design_s9_walk_schedule_documented():
    """The §9 schedule keywords the tests rely on stay documented."""
    sec = DOC.read_text().split("## §9")[1].split("\n## §")[0]
    for needle in ("attempt-major", "overflow walk", "shard_hint",
                   "ONE pallas_call", "serial replay"):
        assert needle in sec, f"DESIGN.md §9 lost {needle!r}"


# ---- DESIGN.md §10: the defragmentation contract --------------------------

def test_design_s10_defrag_documented():
    """The §10 contract keywords tests/test_defrag.py relies on stay
    documented: the plan/execute split, the forwarding-table format,
    the one-kernel waves, and the shard-rebalance policy."""
    sec = DOC.read_text().split("## §10")[1].split("\n## §")[0]
    for needle in ("plan/execute split", "Forwarding(src, dst, sizes)",
                   "ONE `pallas_call` per wave", "class-major rebuild",
                   "rebalance", "most-loaded", "least-loaded",
                   "apply_forwarding", "frag_ratio", "max_moves"):
        assert needle in sec, f"DESIGN.md §10 lost {needle!r}"


# ---- DESIGN.md §11: the fused decode mega-step ----------------------------

def test_design_s11_mega_step_documented():
    """The §11 contract keywords tests/test_serve_mega.py relies on
    stay documented: the five fused stages, the word-offset page
    table, the flag-vector host sync, and the launch-count proof."""
    sec = DOC.read_text().split("## §11")[1].split("\n## §")[0]
    for needle in ("mega_step=True", "Ouroboros.grow", "grow_lanes",
                   "scatter_grant_words", "donate_argnums",
                   "launches_per_tick", "flag vector",
                   "merge_rows", "BENCH_serve.json",
                   "count_pallas_calls", "wpp"):
        assert needle in sec, f"DESIGN.md §11 lost {needle!r}"


# ---- DESIGN.md §12: crash-safe serving ------------------------------------

def test_design_s12_crash_safe_serving_documented():
    """The §12 contract keywords tests/test_serve_snapshot.py and the
    CI crash-restart smoke rely on stay documented: what is
    snapshotted (array tree vs JSON sidecar), the fingerprint
    validation contract and its golden pin, the recompute-vs-reload
    split, the serve-driver wiring, and eviction degradation."""
    sec = DOC.read_text().split("## §12")[1].split("\n## §")[0]
    for needle in ("snapshot()", "restore()", "snapshot_fingerprint",
                   "describe()", "meta.json", "extra",
                   "serve_snapshot_fingerprint.txt", "donate_argnums",
                   "PreemptionGuard", "--snapshot-dir", "--resume",
                   "REQ <uid>", "evictions", "youngest",
                   "refresh_frag_stats", "exit"):
        assert needle in sec, f"DESIGN.md §12 lost {needle!r}"


# ---- DESIGN.md §13: the traffic-replay harness ----------------------------

def test_design_s13_replay_documented():
    """The §13 contract keywords tests/test_replay.py and the fig9
    benchmark rely on stay documented: the traffic model, the
    per-modality page policy, the cancellation states, the parity the
    harness asserts, and the conservation invariant."""
    sec = DOC.read_text().split("## §13")[1].split("\n## §")[0]
    for needle in ("generate_trace", "Poisson", "burst", "abandon",
                   "cancel(uid)", "waiting", "retired",
                   "modality_page_quota", "aux", "replay_pair",
                   "token-for-token", "allocs == frees",
                   "assert_conserved", "p50", "p99",
                   "BENCH_serve.json", "fig9_replay"):
        assert needle in sec, f"DESIGN.md §13 lost {needle!r}"


def test_design_s13_pins_serve_record_schema():
    """§13 documents the BENCH_serve.json record schema; the live
    schema constants must appear there verbatim so the validator and
    the doc cannot drift apart."""
    from benchmarks.common import (REPLAY_CELL_KEYS, SERVE_RECORD_KEYS,
                                   SERVE_RECORD_KINDS)

    sec = DOC.read_text().split("## §13")[1].split("\n## §")[0]
    for kind in SERVE_RECORD_KINDS:
        assert f'"{kind}"' in sec, (
            f"DESIGN.md §13 lost record kind {kind!r}")
    for key in SERVE_RECORD_KEYS:
        assert f"`{key}`" in sec, (
            f"DESIGN.md §13 lost envelope key {key!r}")
    for key in REPLAY_CELL_KEYS:
        assert f"`{key}`" in sec, (
            f"DESIGN.md §13 lost replay telemetry key {key!r}")


# ---- DESIGN.md §14: the observability layer --------------------------------

def test_design_s14_telemetry_word_table_matches_live_layout():
    """§14's telemetry word table is a ``describe()`` rendering for
    the §7 test config; re-render and require every telemetry ctl
    line verbatim, so the documented offsets track
    ``ArenaLayout.tele_fields()`` exactly."""
    sec = DOC.read_text().split("## §14")[1].split("\n## §")[0]
    lay = arena.layout(CFG, "page", "ring")
    tele_lines = [ln for ln in lay.describe().splitlines()
                  if any(f"  {name}" in ln
                         for name, _, _ in lay.tele_fields())]
    assert len(tele_lines) == len(lay.tele_fields())
    for ln in tele_lines:
        assert ln in sec, (
            f"DESIGN.md §14 drifted from the live telemetry layout: "
            f"{ln!r}")
    # every field is prose-documented too
    for name, _, _ in lay.tele_fields():
        assert f"`{name}" in sec, f"DESIGN.md §14 lost field {name!r}"


def test_design_s14_span_taxonomy_and_metric_names_documented():
    """The §14 span taxonomy must list ``trace.PHASES`` verbatim and
    the metric family names the engine publishes must appear, so
    dashboards built from the doc match the live exposition."""
    from repro.obs.trace import PHASES

    sec = DOC.read_text().split("## §14")[1].split("\n## §")[0]
    for phase in PHASES:
        assert f'"{phase}"' in sec, f"DESIGN.md §14 lost span {phase!r}"
    for fam in ("repro_alloc_granted_total", "repro_free_total",
                "repro_alloc_failed_total", "repro_ring_wrap_total",
                "repro_segment_grow_total", "repro_segment_shrink_total",
                "repro_pool_wrap_total",
                "repro_overflow_walk_served_total",
                "repro_arena_frag_ratio", "repro_step_time_ms"):
        assert fam in sec, f"DESIGN.md §14 lost metric family {fam!r}"
    for needle in ("validate_exposition", "require_phases=True",
                   "--metrics-file", "--trace-file", "obs_dump",
                   "jit_first_calls", "drain_telemetry",
                   "publish_metrics"):
        assert needle in sec, f"DESIGN.md §14 lost {needle!r}"
