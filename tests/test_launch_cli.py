"""End-to-end driver tests: the real CLI entrypoints in subprocesses
(train with checkpoint/restart, serve with continuous batching)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT,
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
def test_train_cli_with_resume(tmp_path):
    ck = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "64",
              "--ckpt-dir", ck, "--ckpt-every", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step     5" in r.stdout.replace("step    5", "step     5") \
        or "step    5" in r.stdout
    # restart from the checkpoint and train further
    r2 = _run(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
               "--steps", "8", "--batch", "2", "--seq", "64",
               "--ckpt-dir", ck, "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 6" in r2.stdout


@pytest.mark.slow
def test_serve_cli(tmp_path):
    r = _run(["repro.launch.serve", "--arch", "qwen2-0.5b", "--smoke",
              "--requests", "4", "--max-new", "4", "--max-batch", "2",
              "--max-seq", "96"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 4 requests" in r.stdout
    assert "alloc_failures': 0" in r.stdout
