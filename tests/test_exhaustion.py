"""Adversarial exhaustion and wraparound tests, across the full
implementation matrix.

Every test replays the same trace through the jnp oracle, the
whole-arena Pallas kernel, and the region-blocked compiled lowering in
lockstep, asserting identical grants/failure masks AND word-identical
arenas at every step — the boundaries exercised here (inventory
exhaustion, pool starvation, ring-capacity and segment wraparound) are
exactly where a lowering bug would first desynchronize the three.

On top of cross-implementation equality, the full alloc→free cycle
pins conservation:

- draining a fresh heap to exhaustion, freeing everything, and
  draining again grants the exact same offset set (no page is lost or
  invented by a full cycle);
- the plain page variant restores its entire ``mem`` image word for
  word (ring slots included — a full cycle rewrites them in place);
- chunk variants, after ``compact()``, restore every region word
  except ``free_count`` rows of unbound chunks (meaningless once a
  chunk returns to the pool) and — for virtualized queues — stale slot
  values inside queue-segment chunks; those stale words must never
  fall inside any grantable page (the data-safety half of the claim);
- chunk variants restore the control block exactly (compact rebuilds
  counters from zero, as init does).

The sharded cases pin the overflow walk (DESIGN.md §9): with every
lane homed on one shard, disabling the walk stops the drain at that
shard's capacity, while the full walk recovers each failed allocation
from the neighbors — draining all four shards offset-for-offset
before the allocator ever reports failure — and a full sharded free
cycle conserves the grantable set.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

# Small heap: class-0 inventory drains in a couple of 16-lane batches.
EX_CFG = HeapConfig(total_bytes=1 << 14, chunk_bytes=1 << 10,
                    min_page_bytes=64)
# Tiny chunks: one drain crosses queue-segment boundaries (64 slots)
# and the ring capacity, so cycles wrap both kinds of ring.
WRAP_CFG = HeapConfig(total_bytes=1 << 14, chunk_bytes=256,
                      min_page_bytes=64)
N = 16
SIZE = 64

IMPLS = (("jnp", "auto"), ("pallas", "whole"), ("pallas", "blocked"))

pytestmark = pytest.mark.compiled_lowering


def _mk(cfg, variant):
    return [Ouroboros(cfg, variant, backend, lowering)
            for backend, lowering in IMPLS]


def _assert_lockstep(variant, tag, states):
    ref = jax.tree.leaves(states[0])
    for (backend, lowering), st in zip(IMPLS[1:], states[1:]):
        for a, b in zip(ref, jax.tree.leaves(st)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{variant}: {backend}/{lowering} diverged "
                        f"from the oracle at {tag}")


def _alloc(impls, states, sizes, mask, variant, tag):
    outs = [o.alloc(s, sizes, mask) for o, s in zip(impls, states)]
    states = [s for s, _ in outs]
    offs = [np.asarray(x) for _, x in outs]
    for got, (backend, lowering) in zip(offs[1:], IMPLS[1:]):
        np.testing.assert_array_equal(
            offs[0], got,
            err_msg=f"{variant}: {backend}/{lowering} failure mask "
                    f"diverged at {tag}")
    _assert_lockstep(variant, tag, states)
    return states, offs[0]


def _free(impls, states, fo, fs, variant, tag):
    fm = jnp.asarray(fo >= 0)
    states = [o.free(s, jnp.asarray(fo), jnp.asarray(fs), fm)
              for o, s in zip(impls, states)]
    _assert_lockstep(variant, tag, states)
    return states


def _drain(impls, states, variant, tag):
    """Alloc fixed-size batches until two consecutive all-fail batches;
    returns (states, granted offsets, saw_partial_batch).  13 active
    lanes per batch: inventories are powers of two, so a divisor-of-
    inventory batch width would hit exhaustion exactly between batches
    and never exercise the partial-grant boundary."""
    sizes = jnp.full(N, SIZE, jnp.int32)
    mask = jnp.asarray(np.arange(N) < 13)
    granted, fails, partial, step = [], 0, False, 0
    while fails < 2:
        states, offs = _alloc(impls, states, sizes, mask, variant,
                              f"{tag}[{step}]")
        ok = offs >= 0
        partial |= bool(ok.any() and (~ok).any())
        fails = fails + 1 if not ok.any() else 0
        granted.extend(int(x) for x in offs if x >= 0)
        step += 1
        assert step < 200, "exhaustion never reached"
    return states, granted, partial


def _free_all(impls, states, granted, variant, tag):
    for i in range(0, len(granted), N):
        batch = granted[i:i + N]
        fo = np.full(N, -1, np.int32)
        fo[:len(batch)] = batch
        fs = np.full(N, SIZE, np.int32)
        states = _free(impls, states, fo, fs, variant,
                       f"{tag}[{i // N}]")
    return states


@pytest.mark.parametrize("variant", VARIANTS)
def test_exhaustion_cycle(variant):
    """Drain → free-all → re-drain → free-all → compact, in lockstep
    across the implementation matrix, with the conservation and
    word-restore assertions from the module docstring."""
    impls = _mk(EX_CFG, variant)
    init0 = impls[0].init()
    mem0 = np.asarray(init0.mem).copy()
    ctl0 = np.asarray(init0.ctl).copy()

    states = [o.init() for o in impls]
    states, first, partial = _drain(impls, states, variant, "drain1")
    assert first, "heap granted nothing"
    assert partial, ("exhaustion never produced a partial batch — the "
                     "grant-prefix boundary went unexercised")

    states = _free_all(impls, states, first, variant, "free1")
    states, second, _ = _drain(impls, states, variant, "drain2")
    assert sorted(second) == sorted(first), (
        "a full free cycle changed the grantable offset set")

    states = _free_all(impls, states, second, variant, "free2")
    states = [o.compact(s) for o, s in zip(impls, states)]
    _assert_lockstep(variant, "compact", states)

    mem1 = np.asarray(states[0].mem)
    lay = impls[0].layout
    if variant == "page":
        np.testing.assert_array_equal(
            mem1, mem0, err_msg="page: full cycle must restore the "
                                "entire mem image word for word")
        return
    # granted pages must read back exactly as at init: stale words may
    # only live in queue-segment chunks / unbound free_count rows
    pw = EX_CFG.page_words(EX_CFG.size_to_class(SIZE))
    grantable = np.zeros(lay.mem_words, bool)
    for o in first:
        grantable[o:o + pw] = True
    diff = mem1 != mem0
    assert not (diff & grantable).any(), (
        f"{variant}: full cycle corrupted words inside grantable pages")
    for r in lay.regions:
        if r.name in ("heap", "free_count"):
            continue
        assert not diff[r.offset:r.end].any(), (
            f"{variant}: region {r.name} not restored by the full "
            f"cycle")
    if "chunk" in variant:
        # core counters restore exactly; the telemetry words beyond
        # core_ctl_words are monotonic by design (DESIGN.md §14) and
        # must only have grown over the cycle
        cw = lay.core_ctl_words
        ctl1 = np.asarray(states[0].ctl)
        np.testing.assert_array_equal(
            ctl1[:cw], ctl0[:cw],
            err_msg=f"{variant}: compact must restore the control "
                    f"block exactly")
        assert (ctl1[cw:] >= ctl0[cw:]).all(), (
            f"{variant}: telemetry counters moved backwards")


# ---- sharded exhaustion: the overflow walk drains the neighbors ----------

SHARDS = 4
# 16 chunks per shard (vl queue segments need one chunk per class at
# init, leaving data chunks), and a large page size so a shard's
# inventory drains in a couple of 13-lane batches.
SH_EX_CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 10,
                       min_page_bytes=64)
SH_SIZE = 512


def _mk_sharded(cfg, variant, overflow_walk=None):
    return [Ouroboros(cfg, variant, backend, lowering,
                      num_shards=SHARDS, overflow_walk=overflow_walk)
            for backend, lowering in IMPLS]


def _sharded_alloc(impls, states, sizes, mask, hint, variant, tag):
    outs = [o.alloc(s, sizes, mask, shard_hint=hint)
            for o, s in zip(impls, states)]
    states = [s for s, _ in outs]
    offs = [np.asarray(x) for _, x in outs]
    for got, (backend, lowering) in zip(offs[1:], IMPLS[1:]):
        np.testing.assert_array_equal(
            offs[0], got,
            err_msg=f"{variant}: sharded {backend}/{lowering} diverged "
                    f"at {tag}")
    _assert_lockstep(variant, tag, states)
    return states, offs[0]


def _sharded_drain(impls, states, hint, variant, tag):
    """Fixed-size batches, all lanes homed on ``hint``, until two
    consecutive all-fail batches (as _drain)."""
    sizes = jnp.full(N, SH_SIZE, jnp.int32)
    mask = jnp.asarray(np.arange(N) < 13)
    granted, fails, step = [], 0, 0
    while fails < 2:
        states, offs = _sharded_alloc(impls, states, sizes, mask, hint,
                                      variant, f"{tag}[{step}]")
        ok = offs >= 0
        fails = fails + 1 if not ok.any() else 0
        granted.extend(int(x) for x in offs if x >= 0)
        step += 1
        assert step < 300, "exhaustion never reached"
    return states, granted


@pytest.mark.parametrize("variant", ("page", "va_page", "vl_chunk"))
def test_sharded_overflow_walk_drains_neighbors(variant):
    """All lanes homed on shard 0.  With overflow_walk=0 (the pinned
    path) the drain stops at ONE shard's capacity; with the default
    full walk the same request stream recovers every failed allocation
    from the neighbor shards — draining all S of them, offset for
    offset — before reporting failure.  Lockstep across the whole
    implementation matrix at every step."""
    from repro.core import shards
    Ws = shards.shard_config(SH_EX_CFG, SHARDS).total_words

    # 1) pinned: shard-local exhaustion (static hint, walk 0)
    pinned = _mk_sharded(SH_EX_CFG, variant, overflow_walk=0)
    states = [o.init() for o in pinned]
    states, local_granted = _sharded_drain(pinned, states, 0, variant,
                                           "pinned-drain")
    assert local_granted, "shard 0 granted nothing"
    assert set(o // Ws for o in local_granted) == {0}, \
        "pinned grants must stay on the hinted shard"

    # 2) full walk: the same stream drains all four shards
    walk = _mk_sharded(SH_EX_CFG, variant)
    wstates = [o.init() for o in walk]
    wstates, all_granted = _sharded_drain(walk, wstates, 0, variant,
                                          "walk-drain")
    want = sorted(o % Ws + s * Ws for o in local_granted
                  for s in range(SHARDS))
    assert sorted(all_granted) == want, (
        f"{variant}: the overflow walk must recover exactly the "
        f"neighbors' grantable offsets (every shard's copy of the "
        f"shard-local drain)")

    # 3) free everything and re-drain: conservation holds across the
    #    sharded full cycle too
    for i in range(0, len(all_granted), N):
        batch = all_granted[i:i + N]
        fo = np.full(N, -1, np.int32)
        fo[:len(batch)] = batch
        fs = np.full(N, SH_SIZE, np.int32)
        wstates = _free(walk, wstates, fo, fs, variant,
                        f"walk-free[{i // N}]")
    wstates, again = _sharded_drain(walk, wstates, 0, variant,
                                    "walk-redrain")
    assert sorted(again) == want, (
        f"{variant}: a full sharded free cycle changed the grantable "
        f"offset set")


@pytest.mark.parametrize("variant", VARIANTS)
def test_wraparound_parity(variant):
    """Six full-batch alloc/free cycles on a tiny-chunk heap: ring
    positions wrap capacity and the virtualized families cross segment
    boundaries repeatedly — failure masks and arena words must stay
    identical across the matrix at every step."""
    impls = _mk(WRAP_CFG, variant)
    states = [o.init() for o in impls]
    sizes = jnp.full(N, SIZE, jnp.int32)
    mask = jnp.ones(N, bool)
    for cycle in range(6):
        states, offs = _alloc(impls, states, sizes, mask, variant,
                              f"wrap-alloc{cycle}")
        fo = np.where(offs >= 0, offs, -1).astype(np.int32)
        fs = np.full(N, SIZE, np.int32)
        states = _free(impls, states, fo, fs, variant,
                       f"wrap-free{cycle}")
    # proof the boundaries were exercised: page-kind queues hold one
    # item per page, so six 16-lane cycles push class-0 front past the
    # ring capacity / across segment boundaries.  (Chunk-kind queues
    # hold chunk ids — front moves once per chunk — so for them this
    # test is pure lockstep parity under heavy churn.)
    front0 = int(np.asarray(states[0].ctl)[0])  # class-0 front
    if variant == "page":
        cap = impls[0].layout.region("queue_store").shape[1]
        assert front0 > cap, "trace never wrapped the ring capacity"
    if variant in ("va_page", "vl_page"):
        assert front0 > WRAP_CFG.slots_per_segment(impls[0].family), (
            "trace never crossed a queue-segment boundary")
