"""Defragmentation subsystem lockdown (core/defrag.py, DESIGN.md §10).

Four contracts:

1. **Reclamation** — after a randomized churn trace that strands ≥ 30 %
   of physical pages in sparsely-occupied bound chunks, ONE
   ``Ouroboros.defrag`` wave migrates the stragglers into a dense
   prefix: bound chunks drop to the minimum that holds the live pages,
   emptied chunks retire to the pool, the largest free extent becomes
   chunk-sized again, and an allocation that failed before the wave
   succeeds after it.

2. **Parity** — the migration execute step is bit-identical, word for
   word across the whole arena, between the jnp replay oracle and both
   Pallas lowerings (whole + region-blocked), for ``num_shards ∈ {1,
   4}``; each wave is ONE ``pallas_call`` (asserted on the jaxpr), and
   cross-shard rebalance waves ride the same kernel.

3. **Forwarding** — callers' references survive: ``forward_offsets``
   remaps granted offsets so ``check_pattern`` still passes word for
   word, and the paged KV cache's ``apply_forwarding`` keeps
   post-remap reads identical to pre-defrag reads.

4. **Serving** — the engine coalesces decode-step page growth into one
   transaction, retries through a defrag wave instead of raising
   ``MemoryError``, rebalances shards past the imbalance threshold,
   and surfaces ``defrag_waves``/``pages_migrated``/``frag_ratio``.

The ``compact()`` chunk-rebind path (the §5b predecessor) is locked
down here too, across the same implementation matrix — it was
previously untested against the Pallas lowerings.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, defrag, shards
from repro.kernels.ops import count_pallas_calls

pytestmark = pytest.mark.defrag

# 16 chunks of 512 words; min page 64 B → class-0 chunks hold 32 pages,
# so a churn trace spreads live pages over many chunks quickly.
CFG = HeapConfig(total_bytes=1 << 15, chunk_bytes=1 << 11,
                 min_page_bytes=64)
# four of the above per shard
SH_CFG = HeapConfig(total_bytes=1 << 17, chunk_bytes=1 << 11,
                    min_page_bytes=64)
SHARDS = 4
N = 16
PAGE = 64  # class-0 page bytes

CHUNK_VARIANTS = ("chunk", "va_chunk", "vl_chunk")
LOWERINGS = ("whole", "blocked")


def _impls(cfg, variant, **kw):
    return [("jnp", Ouroboros(cfg, variant, **kw)),
            ("pallas/whole", Ouroboros(cfg, variant, backend="pallas",
                                       lowering="whole", **kw)),
            ("pallas/blocked", Ouroboros(cfg, variant, backend="pallas",
                                         lowering="blocked", **kw))]


def _assert_lockstep(variant, tag, states):
    ref = jax.tree.leaves(states[0][1])
    for lbl, st in states[1:]:
        for a, b in zip(ref, jax.tree.leaves(st)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{variant}: {lbl} diverged from the oracle "
                        f"at {tag}")


def _churn(ouro, state, seed, rounds=14, keep_every=5, shard_hint=None,
           until_full=False):
    """Randomized alloc/free churn leaving scattered live pages.
    ``until_full`` keeps allocating until the heap exhausts (every
    chunk bound) before the free phase.  Returns (state, kept)."""
    rng = np.random.default_rng(seed)
    sizes = jnp.full(N, PAGE, jnp.int32)
    live = []
    kw = {}
    if shard_hint is not None:
        kw["shard_hint"] = jnp.full(N, shard_hint, jnp.int32)
    fails = 0
    for step in range(200):
        if until_full:
            if fails >= 2:
                break
        elif step >= rounds:
            break
        mask = jnp.asarray(rng.random(N) < 0.95)
        state, offs = ouro.alloc(state, sizes, mask, **kw)
        got = [int(o) for o in np.asarray(offs) if o >= 0]
        fails = fails + 1 if not got else 0
        live.extend(got)
    keep_idx = set(range(0, len(live), keep_every))
    kept = [o for i, o in enumerate(live) if i in keep_idx]
    drop = [o for i, o in enumerate(live) if i not in keep_idx]
    rng.shuffle(drop)
    for i in range(0, len(drop), N):
        b = drop[i:i + N]
        fo = np.full(N, -1, np.int32)
        fo[:len(b)] = b
        state = ouro.free(state, jnp.asarray(fo), sizes,
                          jnp.asarray(fo >= 0))
    return state, kept


def _bound_chunks(ouro, state):
    from repro.core import arena
    if ouro.num_shards == 1:
        _, _, meta = arena.unpack(ouro.layout, state)
        return np.asarray(meta.chunk_class)
    lay = ouro.layout.shard
    out = []
    for s in range(ouro.num_shards):
        _, _, meta = arena.unpack(
            lay, arena.Arena(state.mem[s], state.ctl[s]))
        out.append(np.asarray(meta.chunk_class))
    return np.concatenate(out)


# --------------------------------------------------------------------------
# 1. reclamation: churn → strand → one wave → dense prefix
# --------------------------------------------------------------------------

def test_defrag_reclaims_stranded_pages():
    """The acceptance trace: randomized churn strands ≥ 30 % of the
    physical pages (free words locked inside sparsely-occupied bound
    chunks); one wave migrates the stragglers into a dense prefix,
    retires the emptied chunks, restores a chunk-sized free extent,
    and un-fails a chunk-sized allocation — with every surviving
    allocation's data intact through the forwarding remap."""
    ouro = Ouroboros(CFG, "vl_chunk")
    state, kept = _churn(ouro, ouro.init(), seed=0, until_full=True)
    n_live = len(kept)
    ppc = CFG.pages_per_chunk(0)

    # tag the survivors before the wave
    lanes = ((n_live + N - 1) // N) * N
    ko = np.full(lanes, -1, np.int32)
    ko[:n_live] = kept
    sizes = jnp.full(lanes, PAGE, jnp.int32)
    tags = jnp.arange(1000, 1000 + lanes, dtype=jnp.int32)
    state = ouro.write_pattern(state, jnp.asarray(ko), sizes, tags)

    # stranding: ≥ 30 % of physical pages are free-but-locked inside
    # bound chunks, and a chunk-sized allocation fails despite them
    cc = _bound_chunks(ouro, state)
    n_bound = int((cc >= 0).sum())
    stranded_pages = n_bound * ppc - n_live
    total_pages = CFG.total_words // CFG.page_words(0)
    assert stranded_pages / total_pages >= 0.30, (
        f"churn stranded only {stranded_pages}/{total_pages} pages")
    big = jnp.full(4, CFG.chunk_bytes, jnp.int32)
    state, big_offs = ouro.alloc(state, big, jnp.ones(4, bool))
    assert (np.asarray(big_offs) < 0).all(), (
        "heap not actually exhausted for chunk-sized requests")
    fr0 = float(ouro.frag_stats(state)["frag_ratio"])

    state, fwd = ouro.defrag(state)
    moves = int((np.asarray(fwd.src) >= 0).sum())
    assert moves > 0

    # dense prefix: minimal bound chunks, everything else in the pool
    cc2 = _bound_chunks(ouro, state)
    assert int((cc2 >= 0).sum()) == -(-n_live // ppc), (
        "wave left more bound chunks than the live pages need")
    fs = ouro.frag_stats(state)
    assert int(fs["largest_free_extent"]) >= CFG.words_per_chunk
    assert float(fs["frag_ratio"]) < fr0

    # survivors are intact at their forwarded offsets
    ko2 = np.asarray(defrag.forward_offsets(fwd, jnp.asarray(ko)))
    ok = np.asarray(ouro.check_pattern(state, jnp.asarray(ko2), sizes,
                                       tags))
    assert ok[:n_live].all(), "migration corrupted live words"

    # the failed chunk-sized allocation now succeeds
    state, big_offs = ouro.alloc(state, big, jnp.ones(4, bool))
    assert (np.asarray(big_offs) >= 0).any(), (
        "defrag failed to reclaim a chunk-sized extent")


def test_page_kind_defrag_is_noop():
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    st2, fwd = ouro.defrag(st)
    assert int((np.asarray(fwd.src) >= 0).sum()) == 0
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_defrag_knobs_validated():
    with pytest.raises(ValueError, match="max_moves"):
        o = Ouroboros(CFG, "vl_chunk")
        o.defrag(o.init(), max_moves=0)
    with pytest.raises(ValueError, match="rebalance"):
        o = Ouroboros(CFG, "vl_chunk")
        o.rebalance(o.init())


# --------------------------------------------------------------------------
# 2. parity: jnp oracle vs both lowerings, single and sharded
# --------------------------------------------------------------------------

@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", CHUNK_VARIANTS)
def test_defrag_parity_across_lowerings(variant):
    """Churn → wave → more churn → wave, in lockstep: identical
    forwarding tables and word-identical arenas after every wave."""
    impls = _impls(CFG, variant)
    states = [(lbl, o.init()) for lbl, o in impls]
    for round_ in range(2):
        states = [(lbl, _churn(o, st, seed=round_)[0])
                  for (lbl, o), (_, st) in zip(impls, states)]
        outs = [(lbl, o.defrag(st, max_moves=64))
                for (lbl, o), (_, st) in zip(impls, states)]
        ref_fwd = outs[0][1][1]
        for lbl, (_, fwd) in outs[1:]:
            for a, b in zip(ref_fwd, fwd):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{variant}/{lbl}: forwarding diverged at "
                            f"wave {round_}")
        states = [(lbl, st) for lbl, (st, _) in outs]
        _assert_lockstep(variant, f"wave {round_}", states)


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", ("chunk", "vl_chunk"))
def test_sharded_defrag_parity(variant):
    """num_shards=4: one wave defragments every shard, still ONE kernel,
    bit-identical across the implementation matrix."""
    impls = _impls(SH_CFG, variant, num_shards=SHARDS)
    states = []
    for lbl, o in impls:
        st = o.init()
        st, _ = _churn(o, st, seed=1, shard_hint=0)
        st, _ = _churn(o, st, seed=2, shard_hint=2)
        states.append((lbl, st))
    outs = [(lbl, o.defrag(st, max_moves=64))
            for (lbl, o), (_, st) in zip(impls, states)]
    ref_fwd = outs[0][1][1]
    for lbl, (_, fwd) in outs[1:]:
        np.testing.assert_array_equal(
            np.asarray(ref_fwd.src), np.asarray(fwd.src),
            err_msg=f"{variant}/{lbl}: sharded forwarding diverged")
    states = [(lbl, st) for lbl, (st, _) in outs]
    _assert_lockstep(variant, "sharded wave", states)


@pytest.mark.compiled_lowering
def test_rebalance_parity_and_load_shift():
    """Cross-shard rebalance: bit-identical across the matrix, moves
    live words from the most- to the least-loaded shard (claiming pool
    chunks on the receiver), and survivors stay word-intact through
    the forwarding remap."""
    impls = _impls(SH_CFG, "vl_chunk", num_shards=SHARDS)
    states, kept = [], None
    for lbl, o in impls:
        st = o.init()
        st, k0 = _churn(o, st, seed=3, shard_hint=0)
        states.append((lbl, st))
        kept = k0
    lanes = ((len(kept) + N - 1) // N) * N
    ko = np.full(lanes, -1, np.int32)
    ko[:len(kept)] = kept
    sizes = jnp.full(lanes, PAGE, jnp.int32)
    tags = jnp.arange(500, 500 + lanes, dtype=jnp.int32)
    states = [(lbl, o.write_pattern(st, jnp.asarray(ko), sizes, tags))
              for (lbl, o), (_, st) in zip(impls, states)]

    m0, c0 = (np.asarray(states[0][1].mem), np.asarray(states[0][1].ctl))
    lw0 = np.asarray(shards.shard_live_words(SH_CFG, SHARDS, "chunk",
                                             "vl", m0, c0))
    outs = [(lbl, o.rebalance(st, max_moves=64))
            for (lbl, o), (_, st) in zip(impls, states)]
    ref_st, ref_fwd = outs[0][1]
    for lbl, (st, fwd) in outs[1:]:
        np.testing.assert_array_equal(
            np.asarray(ref_fwd.src), np.asarray(fwd.src),
            err_msg=f"{lbl}: rebalance plan diverged")
    _assert_lockstep("vl_chunk", "rebalance",
                     [(lbl, st) for lbl, (st, _) in outs])

    assert int((np.asarray(ref_fwd.src) >= 0).sum()) > 0
    lw1 = np.asarray(shards.shard_live_words(
        SH_CFG, SHARDS, "chunk", "vl", np.asarray(ref_st.mem),
        np.asarray(ref_st.ctl)))
    donor, recv = int(np.argmax(lw0)), int(np.argmin(lw0))
    assert lw1[donor] < lw0[donor] and lw1[recv] > lw0[recv], (
        f"load did not shift donor→receiver: {lw0} → {lw1}")
    ko2 = np.asarray(defrag.forward_offsets(ref_fwd, jnp.asarray(ko)))
    assert (ko2 != ko).any(), "rebalance left every kept page in place"
    ok = np.asarray(impls[0][1].check_pattern(ref_st, jnp.asarray(ko2),
                                              sizes, tags))
    assert ok[:len(kept)].all(), "rebalance corrupted live words"


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("lowering", LOWERINGS)
@pytest.mark.parametrize("num_shards", (1, SHARDS))
def test_single_pallas_call_per_wave(lowering, num_shards):
    """A migration wave — plan AND execute — lowers to exactly one
    pallas_call under backend="pallas" (both lowerings, sharded or
    not); the jnp oracle lowers to zero.  Rebalance rides the same
    kernel."""
    cfg = SH_CFG if num_shards > 1 else CFG
    for backend, want in (("pallas", 1), ("jnp", 0)):
        o = Ouroboros(cfg, "vl_chunk", backend, lowering,
                      num_shards=num_shards)
        st = o.init()
        j = jax.make_jaxpr(lambda s: o.defrag(s, max_moves=32))(st)
        assert count_pallas_calls(j) == want, (
            f"{backend}/{lowering}/shards{num_shards}: defrag wave is "
            f"not a single fused kernel")
        if num_shards > 1:
            j = jax.make_jaxpr(lambda s: o.rebalance(s, max_moves=32))(
                st)
            assert count_pallas_calls(j) == want, (
                f"{backend}/{lowering}: rebalance wave is not a single "
                f"fused kernel")


# --------------------------------------------------------------------------
# 3. the compact() chunk-rebind path across the same matrix (satellite)
# --------------------------------------------------------------------------

@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", CHUNK_VARIANTS)
def test_compact_lockstep_across_lowerings(variant):
    """compact() interleaved mid-trace: states built by the Pallas
    lowerings stay word-identical to the oracle through the rebind and
    keep serving identical grants afterwards (previously compact was
    only exercised on jnp-built states)."""
    impls = _impls(CFG, variant)
    states = [(lbl, o.init()) for lbl, o in impls]
    sizes = jnp.full(N, PAGE, jnp.int32)
    ones = jnp.ones(N, bool)
    for round_ in range(3):
        outs = [o.alloc(st, sizes, ones)
                for (_, o), (_, st) in zip(impls, states)]
        offs0 = np.asarray(outs[0][1])
        for (lbl, _), (_, offs) in zip(impls[1:], outs[1:]):
            np.testing.assert_array_equal(offs0, np.asarray(offs))
        states = [(lbl, st)
                  for (lbl, _), (st, _) in zip(impls, outs)]
        fo = np.where(offs0 >= 0, offs0, -1).astype(np.int32)
        half = jnp.asarray(np.arange(N) % 2 == 0) & jnp.asarray(fo >= 0)
        states = [(lbl, o.free(st, jnp.asarray(fo), sizes, half))
                  for (lbl, o), (_, st) in zip(impls, states)]
        states = [(lbl, o.compact(st))
                  for (lbl, o), (_, st) in zip(impls, states)]
        _assert_lockstep(variant, f"compact {round_}", states)


@pytest.mark.compiled_lowering
def test_sharded_compact_lockstep():
    impls = _impls(SH_CFG, "vl_chunk", num_shards=SHARDS)
    states = [(lbl, _churn(o, o.init(), seed=5, shard_hint=1)[0])
              for lbl, o in impls]
    states = [(lbl, o.compact(st))
              for (lbl, o), (_, st) in zip(impls, states)]
    _assert_lockstep("vl_chunk", "sharded compact", states)
    sizes = jnp.full(N, PAGE, jnp.int32)
    outs = [o.alloc(st, sizes, jnp.ones(N, bool))
            for (_, o), (_, st) in zip(impls, states)]
    offs0 = np.asarray(outs[0][1])
    for (lbl, _), (_, offs) in zip(impls[1:], outs[1:]):
        np.testing.assert_array_equal(offs0, np.asarray(offs),
                                      err_msg=f"{lbl} post-compact")


# --------------------------------------------------------------------------
# 4. forwarding consumers: KV cache remap
# --------------------------------------------------------------------------

def test_kv_apply_forwarding_preserves_reads():
    """Paged-KV reads through the page table are word-identical before
    and after a defrag remap (rows moved + table rewritten in one
    step)."""
    from repro.paged import kv_cache as KV
    rng = np.random.default_rng(0)
    L, NP, B, P, H, D = 2, 8, 2, 3, 1, 4
    kv = KV.init_paged_kv(L, NP, B, P, H, D, kv_dtype=jnp.float32)
    kv = kv._replace(
        layers=kv.layers._replace(
            k=jnp.asarray(rng.standard_normal(kv.layers.k.shape),
                          jnp.float32),
            v=jnp.asarray(rng.standard_normal(kv.layers.v.shape),
                          jnp.float32)),
        page_table=jnp.asarray([[5, 2, -1], [7, -1, -1]], jnp.int32),
        seq_lens=jnp.asarray([40, 16], jnp.int32))

    def gather(kv):
        pt = jnp.maximum(kv.page_table, 0)
        ok = (kv.page_table >= 0)[None, :, :, None, None, None]
        return np.asarray(jnp.where(ok, kv.layers.k[:, pt], 0.0))

    before = gather(kv)
    wpp = 64
    fwd = defrag.Forwarding(
        src=jnp.asarray([5 * wpp, 7 * wpp, -1], jnp.int32),
        dst=jnp.asarray([0 * wpp, 1 * wpp, -1], jnp.int32),
        sizes=jnp.asarray([256, 256, 0], jnp.int32))
    kv2 = KV.apply_forwarding(kv, fwd, wpp)
    np.testing.assert_array_equal(
        np.asarray(kv2.page_table),
        np.asarray([[0, 2, -1], [1, -1, -1]], np.int32))
    np.testing.assert_array_equal(gather(kv2), before)


def test_forward_offsets_passthrough():
    fwd = defrag.Forwarding(src=jnp.asarray([64, -1], jnp.int32),
                            dst=jnp.asarray([0, -1], jnp.int32),
                            sizes=jnp.asarray([256, 0], jnp.int32))
    offs = jnp.asarray([64, 128, -1], jnp.int32)
    got = np.asarray(defrag.forward_offsets(fwd, offs))
    np.testing.assert_array_equal(got, [0, 128, -1])


# --------------------------------------------------------------------------
# 5. fragmentation observability
# --------------------------------------------------------------------------

def test_frag_stats_track_stranding_and_recovery():
    ouro = Ouroboros(CFG, "vl_chunk")
    st = ouro.init()
    fs0 = ouro.frag_stats(st)
    assert int(fs0["free_words"]) > 0
    st, _ = _churn(ouro, st, seed=7)
    fs1 = ouro.frag_stats(st)
    assert float(fs1["frag_ratio"]) > float(fs0["frag_ratio"])
    st, _ = ouro.defrag(st)
    fs2 = ouro.frag_stats(st)
    assert float(fs2["frag_ratio"]) < float(fs1["frag_ratio"])
    assert int(fs2["largest_free_extent"]) >= CFG.words_per_chunk


def test_frag_stats_sharded_shapes():
    ouro = Ouroboros(SH_CFG, "vl_chunk", num_shards=SHARDS)
    fs = ouro.frag_stats(ouro.init())
    assert fs["free_words"].shape == (SHARDS,)
    assert fs["frag_ratio"].shape == (SHARDS,)


def test_frag_stats_page_kind():
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    fs = ouro.frag_stats(st)
    assert int(fs["free_words"]) > 0
    # drain class 0 entirely: the largest grantable extent shrinks only
    # if every bigger class drained too — here it stays chunk-sized
    assert int(fs["largest_free_extent"]) == CFG.words_per_chunk


# --------------------------------------------------------------------------
# 6. serving engine: coalesced growth, defrag-on-failure, rebalance
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_arch
    from repro.models.model import build_model
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_engine_survives_exhaustion_trace(tiny_model, rng):
    """A heap-exhaustion trace that previously raised
    ``MemoryError("KV heap exhausted mid-flight")``: a co-tenant binds
    most chunks to a large size class through the same allocator and
    releases them — sticky bindings strand the chunks for the engine's
    256 B pages.  The engine now reclaims them with a defrag wave and
    finishes every request."""
    cfg, m, params = tiny_model
    from repro.serve.engine import ServingEngine
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32, num_pages=16)
    n = 16
    big = jnp.full(n, 2048, jnp.int32)
    st, offs = eng.ouro.alloc(eng.alloc_state, big, jnp.ones(n, bool))
    granted = np.asarray(offs) >= 0
    assert granted.any()
    eng.alloc_state = eng.ouro.free(st, offs, big, jnp.asarray(granted))

    for _ in range(2):
        eng.submit(rng.integers(2, cfg.vocab_size, 40), max_new_tokens=8)
    done = eng.run_until_done(100)
    assert len(done) == 2
    assert all(len(r.out_tokens) == 8 for r in done)
    assert eng.stats["alloc_failures"] > 0, (
        "trace never exhausted the heap — nothing was tested")
    assert eng.stats["defrag_waves"] > 0
    assert eng.stats["frag_ratio"] is not None


def test_engine_decode_growth_is_one_transaction(tiny_model, rng):
    """Decode-step page growth coalesces across the active batch: a
    step where EVERY slot crosses a page boundary issues exactly ONE
    bulk alloc transaction (previously one per slot)."""
    cfg, m, params = tiny_model
    from repro.serve.engine import ServingEngine
    eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                        kv_dtype=jnp.float32)
    # identical prompt lengths → the slots cross page boundaries in
    # the same step (page = 16 tokens; admit leaves slot_len = 15)
    for _ in range(3):
        eng.submit(rng.integers(2, cfg.vocab_size, 14),
                   max_new_tokens=8)
    eng.step()  # admission
    crossed = False
    for _ in range(6):
        before = eng.stats["alloc_txns"]
        grants_before = eng.stats["allocs"]
        eng.step()
        txns = eng.stats["alloc_txns"] - before
        grants = eng.stats["allocs"] - grants_before
        assert txns <= 1, (
            f"decode step issued {txns} alloc transactions for one "
            f"batch")
        if grants >= 3:
            crossed = True  # all three slots grew in ONE transaction
    assert crossed, "no step grew all three slots together"


def test_engine_rebalance_trigger_and_output_parity(tiny_model, rng):
    """Sharded engine past the imbalance threshold: a rebalance wave
    fires, live pages spread across shards, and greedy outputs stay
    IDENTICAL to an engine that never rebalances (the KV remap is
    invisible to decoding)."""
    cfg, m, params = tiny_model
    from repro.serve.engine import ServingEngine
    prompt = rng.integers(2, cfg.vocab_size, 30)

    eng = ServingEngine(m, params, max_batch=2, max_seq=96,
                        kv_dtype=jnp.float32, compute_dtype=jnp.float32,
                        num_shards=2, rebalance_threshold=1)
    eng.submit(prompt, max_new_tokens=10)  # slot 0 → shard 0 only
    done = eng.run_until_done(100)
    assert len(done) == 1
    assert eng.stats["rebalance_waves"] > 0, (
        "imbalance never triggered a rebalance wave")
    assert eng.stats["pages_migrated"] > 0

    ref = ServingEngine(m, params, max_batch=2, max_seq=96,
                        kv_dtype=jnp.float32, compute_dtype=jnp.float32,
                        num_shards=2)
    ref.submit(prompt, max_new_tokens=10)
    ref_done = ref.run_until_done(100)
    assert done[0].out_tokens == ref_done[0].out_tokens, (
        "rebalancing changed decoded tokens — the KV remap leaked")


def test_engine_validates_rebalance_threshold():
    from repro.serve.engine import ServingEngine
    with pytest.raises(ValueError, match="rebalance_threshold"):
        ServingEngine(None, None, rebalance_threshold=4)
    with pytest.raises(ValueError, match="rebalance_threshold"):
        ServingEngine(None, None, num_shards=2, rebalance_threshold=0)


def test_engine_surfaces_frag_stats(tiny_model, rng):
    cfg, m, params = tiny_model
    from repro.serve.engine import ServingEngine
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32, num_shards=2)
    assert isinstance(eng.stats["frag_ratio"], list)
    assert len(eng.stats["free_words"]) == 2
    eng.submit(rng.integers(2, cfg.vocab_size, 8), max_new_tokens=3)
    eng.run_until_done(50)
    fs = eng.refresh_frag_stats()
    assert all(x >= 0 for x in eng.stats["largest_free_extent"])
    assert fs is not None
