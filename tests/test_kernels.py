"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret
mode on CPU (the compiled path's exact semantics)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# ---- ring_window -----------------------------------------------------------

@pytest.mark.parametrize("C,cap,m", [(1, 64, 16), (5, 256, 64),
                                     (10, 1024, 1024), (3, 128, 128)])
def test_ring_window_shapes(C, cap, m, rng):
    store = jnp.asarray(rng.integers(0, 10**6, (C, cap)), jnp.int32)
    front = jnp.asarray(rng.integers(0, cap, C), jnp.int32)
    counts = jnp.asarray(rng.integers(0, m + 1, C), jnp.int32)
    got = ops.ring_window(store, front, counts, m=m)
    want = ref.ring_window_ref(store, front, counts, m)
    np.testing.assert_array_equal(got, want)


def test_ring_window_wraparound(rng):
    store = jnp.arange(32, dtype=jnp.int32)[None]
    front = jnp.asarray([30], jnp.int32)
    counts = jnp.asarray([5], jnp.int32)
    got = np.asarray(ops.ring_window(store, front, counts, m=8))
    assert list(got[0][:5]) == [30, 31, 0, 1, 2]
    assert (got[0][5:] == -1).all()


# ---- bitmap_select -----------------------------------------------------------

@pytest.mark.parametrize("w", [32, 64, 256])
@pytest.mark.parametrize("k", [0, 1, 7, 100, 10**6])
def test_bitmap_select_sweep(w, k, rng):
    words = jnp.asarray(
        rng.integers(0, 2**32, w, dtype=np.uint64), jnp.uint32)
    got = ops.bitmap_select(words, k)
    want = ref.bitmap_select_ref(words, k)
    np.testing.assert_array_equal(got, want)


def test_bitmap_select_indices(rng):
    words = jnp.asarray([0b1011, 0, 1], jnp.uint32)
    idx, valid = ops.bitmap_select_indices(
        jnp.pad(words, (0, 29)), 3, max_k=4)
    assert list(np.asarray(idx)[:3]) == [0, 1, 3]
    assert list(np.asarray(valid)) == [True, True, True, False]


# ---- paged_attention ----------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,D,page,P", [
    (1, 4, 4, 128, 16, 4),     # MHA
    (2, 8, 2, 128, 16, 6),     # GQA
    (2, 8, 1, 64, 8, 8),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, Hq, Hkv, D, page, P, dtype, rng):
    NP = B * P + 4
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), dtype)
    kp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), dtype)
    pt = jnp.asarray(
        rng.choice(NP, (B, P), replace=False), jnp.int32)
    pt = pt.at[0, P - 1:].set(-1)
    sl = jnp.asarray(rng.integers(1, (P - 1) * page, B), jnp.int32)
    got = ops.paged_attention(q, kp, vp, pt, sl)
    want = ref.paged_attention_ref(q, kp, vp, pt, sl)
    tol = 3e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_paged_attention_word_offset_table(rng):
    """Mega-step table format: ``page_table`` holding raw arena WORD
    offsets (page id × wpp, holes −1) with ``wpp`` passed through must
    match the page-id table exactly — the division happens in the
    scalar-prefetch index map, and −1 holes stay invalid under floor
    division."""
    B, Hq, Hkv, D, page, P, wpp = 2, 8, 2, 128, 16, 6, 64
    NP = B * P + 4
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), jnp.float32)
    pt = jnp.asarray(rng.choice(NP, (B, P), replace=False), jnp.int32)
    pt = pt.at[0, P - 1:].set(-1)
    sl = jnp.asarray(rng.integers(1, (P - 1) * page, B), jnp.int32)
    want = ops.paged_attention(q, kp, vp, pt, sl)
    words = jnp.where(pt >= 0, pt * wpp, -1)
    got = ops.paged_attention(q, kp, vp, words, sl, wpp=wpp)
    np.testing.assert_array_equal(got, want)


# ---- ssd_scan ------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 32, 16),
    (2, 128, 4, 32, 2, 64, 32),
    (1, 256, 8, 64, 1, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, L, H, P, G, N, chunk, dtype, rng):
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, H), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, L, G, N)), dtype)
    c = jnp.asarray(rng.standard_normal((B, L, G, N)), dtype)
    y, hf = ops.ssd_scan(x, dt, a, b, c, chunk=chunk)
    yr, hr = ref.ssd_ref(x, dt, a, b, c)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(y, yr, atol=tol * 10, rtol=tol * 10)
    np.testing.assert_allclose(hf, hr, atol=tol * 10, rtol=tol * 10)


def test_ssd_scan_chained_states(rng):
    """Two chained half-length scans == one full scan (decode contract)."""
    B, L, H, P, G, N = 1, 64, 2, 16, 1, 32
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, H), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    y_full, h_full = ops.ssd_scan(x, dt, a, b, c, chunk=16)
    h = L // 2
    y1, s1 = ops.ssd_scan(x[:, :h], dt[:, :h], a, b[:, :h], c[:, :h],
                          chunk=16)
    y2, s2 = ops.ssd_scan(x[:, h:], dt[:, h:], a, b[:, h:], c[:, h:],
                          h0=s1, chunk=16)
    np.testing.assert_allclose(
        np.concatenate([y1, y2], 1), y_full, atol=1e-4)
    np.testing.assert_allclose(s2, h_full, atol=1e-4)


# ---- kernel/core integration ----------------------------------------------------

def test_ring_window_matches_page_alloc(rng):
    """The kernel computes exactly what the page allocator's bulk
    dequeue gathers (rank-dense grant windows)."""
    from repro.core import HeapConfig, groups
    from repro.core import page_alloc, queues
    cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                     min_page_bytes=16)
    st = page_alloc.init(cfg, "ring")
    sizes = jnp.asarray(rng.choice([16, 64, 256], 32), jnp.int32)
    from repro.core.heap import size_to_class_device
    cls = size_to_class_device(cfg, sizes)
    valid = cls < cfg.num_classes
    rank, counts = groups.masked_rank(cls, valid, cfg.num_classes)
    m = 32
    win = ops.ring_window(st.q.store, st.q.front % st.q.store.shape[1],
                          jnp.minimum(counts, m), m=m)
    st2, offs = page_alloc.alloc(cfg, "ring", st, sizes, valid)
    offs = np.asarray(offs)
    win = np.asarray(win)
    for i in range(32):
        if offs[i] >= 0:
            assert win[int(cls[i]), int(rank[i])] == offs[i]


def test_pallas_ring_path_equals_jnp_path(rng):
    """core/page_alloc with backend="pallas": identical grants & state
    (the fused-transaction form of the old USE_PALLAS_RING toggle)."""
    from repro.core import HeapConfig, page_alloc
    import jax.numpy as jnp
    cfg = HeapConfig(total_bytes=1 << 17, chunk_bytes=1 << 11,
                     min_page_bytes=16)
    sizes = jnp.asarray(rng.choice([16, 64, 256, 1000], 48), jnp.int32)
    mask = jnp.asarray(rng.random(48) < 0.9)

    st = page_alloc.init(cfg, "ring")
    s_ref, o_ref = page_alloc.alloc(cfg, "ring", st, sizes, mask, "jnp")
    s_ker, o_ker = page_alloc.alloc(cfg, "ring", st, sizes, mask,
                                    "pallas")
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_ker))
    np.testing.assert_array_equal(np.asarray(s_ref.q.front),
                                  np.asarray(s_ker.q.front))


# ---- alloc_txn fused transactions ------------------------------------------

def test_ring_txn_pop_matches_bulk_dequeue(rng):
    """Fused pop (limit=False) == queues.ring_bulk_dequeue, including
    wraparound, masked lanes, and invalid classes."""
    from repro.core import HeapConfig, groups, queues
    C, cap, n = 5, 48, 33
    cfg = HeapConfig()
    store = jnp.asarray(rng.integers(0, 10**6, (C, cap)), jnp.int32)
    front = jnp.asarray(rng.integers(0, 100, C), jnp.int32)
    back = front + jnp.asarray(rng.integers(0, cap + 1, C), jnp.int32)
    q = queues.RingState(store=store, front=front, back=back)
    cls = jnp.asarray(rng.integers(0, C + 1, n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.8) & (cls < C)
    rank, _ = groups.masked_rank(cls, mask, C)

    q_ref, _, v_ref = queues.ring_bulk_dequeue(cfg, q, None, cls, rank,
                                               mask)
    v_ker, nf = ops.ring_txn_pop(store, front, back, cls, mask,
                                 limit=False)
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_ker))
    np.testing.assert_array_equal(np.asarray(q_ref.front), np.asarray(nf))


def test_ring_txn_push_matches_bulk_enqueue(rng):
    from repro.core import HeapConfig, groups, queues
    C, cap, n = 4, 32, 21
    cfg = HeapConfig()
    store = jnp.asarray(rng.integers(0, 10**6, (C, cap)), jnp.int32)
    back = jnp.asarray(rng.integers(0, 100, C), jnp.int32)
    q = queues.RingState(store=store, front=back - 3, back=back)
    cls = jnp.asarray(rng.integers(0, C + 1, n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.8) & (cls < C)
    vals = jnp.asarray(rng.integers(0, 10**6, n), jnp.int32)
    rank, _ = groups.masked_rank(cls, mask, C)

    q_ref, _ = queues.ring_bulk_enqueue(cfg, q, None, cls, rank, vals,
                                        mask)
    st_ker, nb = ops.ring_txn_push(store, back, cls, vals, mask)
    np.testing.assert_array_equal(np.asarray(q_ref.store),
                                  np.asarray(st_ker))
    np.testing.assert_array_equal(np.asarray(q_ref.back), np.asarray(nb))


@pytest.mark.parametrize("ppc,bw", [(32, 1), (128, 4)])
def test_chunk_txn_claim_matches_select_free_pages(ppc, bw, rng):
    from repro.core import chunk_alloc
    for take in (0, 3, 10**4):
        row = jnp.asarray(
            rng.integers(0, 2**32, bw, dtype=np.uint64), jnp.uint32)
        pi_ref, sel_ref = chunk_alloc._select_free_pages(
            row, ppc, jnp.int32(take))
        pi, nrow, nsel = ops.chunk_txn_claim(row, jnp.int32(take), ppc=ppc)
        np.testing.assert_array_equal(np.asarray(pi_ref), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(sel_ref),
                                      np.asarray(pi) >= 0)
        assert int(nsel[0]) == int(np.asarray(sel_ref).sum())
        # claimed bits set, nothing else changed
        got = np.asarray(nrow)
        exp = np.asarray(row).copy()
        for p in np.asarray(pi):
            if p >= 0:
                exp[p // 32] |= np.uint32(1) << np.uint32(p % 32)
        np.testing.assert_array_equal(exp, got)


def test_paged_attention_kernel_matches_serving_path(rng):
    """kernels/paged_attention (Pallas) == paged/kv_cache.paged_attend1
    (the GSPMD serving path) on identical paged state."""
    from repro.paged import kv_cache as KV
    B, Hq, Hkv, D, page, P = 2, 4, 2, 128, 16, 4
    NP = B * P
    kp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, page, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    pt = (jnp.arange(B)[:, None] * P + jnp.arange(P)[None, :]).astype(
        jnp.int32)
    sl = jnp.asarray([37, 61], jnp.int32)

    kernel = ops.paged_attention(q[:, 0], kp, vp, pt, sl)
    lay = KV.KVLayer(k=kp, v=vp, k_scale=None, v_scale=None)
    serving = KV.paged_attend1(lay, pt, sl, q)[:, 0]
    np.testing.assert_allclose(kernel, serving, atol=2e-5, rtol=2e-5)
