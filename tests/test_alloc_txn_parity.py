"""Differential harness: the fused-arena Pallas backend vs the jnp
reference oracle, on randomized alloc/free/write/check traces.

For every variant the same trace is replayed through
``Ouroboros(cfg, variant, backend="jnp")`` and ``backend="pallas"``
under BOTH kernel lowerings — ``whole`` (full-arena refs) and
``blocked`` (the region-blocked compiled lowering, DESIGN.md §8) —
(interpret mode on CPU — the compiled path's exact semantics) and all
three executions must be **bit-identical** at every step:

  - granted offsets and failure masks (−1 lanes)
  - ``check_pattern`` integrity verdicts
  - the full arena: every word of ``mem`` (heap, pool ring, queue ring
    or segment directory, chunk bitmaps) and of ``ctl`` (every counter)

Beyond lockstep equality this file pins the arena-era contracts:

  - one ``pallas_call`` per whole transaction (alloc and free), for all
    six variants and BOTH lowerings, asserted on the jaxpr — the
    fusion criterion survives the region-blocked refactor;
  - va/vl segment grow/shrink runs *inside* that one kernel: the
    small-chunk config below forces directory/chain growth and
    segment reclaim mid-trace (asserted via the pool counters, which
    only move on segment traffic for page-kind virtualized variants);
  - ``init`` state is backend-free, so a live heap can switch backends
    mid-stream and stay on the oracle's trajectory.

The sharded allocator (core/shards.py, DESIGN.md §9) extends the
matrix: with ``num_shards=4`` every variant is additionally held
bit-identical — offsets, failure lanes, every word of every shard —
to an explicit ``SerialShardOracle`` built from four independent
single-shard jnp allocators replayed in the documented
attempt-major/shard-minor schedule, and the one-kernel property is
asserted for the sharded grids of BOTH lowerings.

``--runslow`` unlocks the long replays (more ops, more seeds, both
configs × all six variants) that the scheduled CI job runs nightly.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
SIZES = [16, 24, 100, 256, 1000, 2048, 8192]  # 8192 > chunk → must fail

# Tiny chunks (16 words, so 15/16 queue slots per segment) make the
# virtualized queues cross a segment boundary every lane-width of
# traffic: init fills class 0 to exactly a segment edge, so the first
# class-0 free grows the directory/chain and a handful of allocs
# consume a whole segment and return it to the pool (shrink) — both
# paths of the in-kernel walk fire within a short trace.
GROW_CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=64,
                      min_page_bytes=16)
GROW_SIZES = [16, 32, 64, 128]                # 128 > chunk → must fail

N = 16       # fixed lane width so every transaction reuses one jit cache
OPS = 8
SEEDS = (0, 1)

# the Pallas implementations replayed in lockstep against the oracle
LOWERINGS = ("whole", "blocked")

VIRT_VARIANTS = tuple(v for v in VARIANTS if "_" in v)


def _assert_state_equal(variant, step, sj, sp):
    la, lb = jax.tree.leaves(sj), jax.tree.leaves(sp)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{variant}: state diverged after op {step}")


def _replay(variant, seed, cfg=CFG, sizes_menu=SIZES, ops=OPS):
    """Lockstep replay: the jnp oracle vs the Pallas backend under
    every kernel lowering, word-identical arenas after every op."""
    rng = np.random.default_rng(seed)
    oj = Ouroboros(cfg, variant, backend="jnp")
    ops_p = [Ouroboros(cfg, variant, backend="pallas", lowering=lw)
             for lw in LOWERINGS]
    sj = oj.init()
    sps = [o.init() for o in ops_p]
    for lw, sp in zip(LOWERINGS, sps):
        _assert_state_equal(f"{variant}/{lw}", "init", sj, sp)
    pool_sl = slice(oj.layout.off_pool_front, oj.layout.off_pool_back + 1)
    pool_ctr0 = np.asarray(sj.ctl)[pool_sl].copy()
    pool_moved = False

    live = []  # (offset, size) granted and not yet freed
    tagc = 0
    for step in range(ops):
        kind = rng.choice(["alloc", "free"]) if live else "alloc"
        if kind == "alloc":
            sizes = jnp.asarray(rng.choice(sizes_menu, N), jnp.int32)
            mask = jnp.asarray(rng.random(N) < 0.85)
            sj, offj = oj.alloc(sj, sizes, mask)
            offj = np.asarray(offj)
            outs = [o.alloc(s, sizes, mask)
                    for o, s in zip(ops_p, sps)]
            sps = [s for s, _ in outs]
            for lw, (_, offp) in zip(LOWERINGS, outs):
                np.testing.assert_array_equal(
                    offj, np.asarray(offp),
                    err_msg=f"{variant}/{lw}: offsets/failure masks "
                            f"diverged at op {step}")
            tags = jnp.arange(tagc, tagc + N, dtype=jnp.int32)
            tagc += N
            so = jnp.asarray(offj, jnp.int32)
            sj = oj.write_pattern(sj, so, sizes, tags)
            sps = [o.write_pattern(s, so, sizes, tags)
                   for o, s in zip(ops_p, sps)]
            cj = np.asarray(oj.check_pattern(sj, so, sizes, tags))
            for lw, o, s in zip(LOWERINGS, ops_p, sps):
                cp = np.asarray(o.check_pattern(s, so, sizes, tags))
                np.testing.assert_array_equal(
                    cj, cp, err_msg=f"{variant}/{lw}: integrity "
                                    f"verdicts diverged at op {step}")
            live.extend((int(o), int(s))
                        for o, s in zip(offj, np.asarray(sizes)) if o >= 0)
        else:
            k = min(len(live), int(rng.integers(1, N + 1)))
            pick = rng.choice(len(live), k, replace=False)
            drop = [live[i] for i in pick]
            live = [x for i, x in enumerate(live) if i not in set(pick)]
            fo = np.full(N, -1, np.int32)
            fs = np.zeros(N, np.int32)
            fo[:k] = [o for o, _ in drop]
            fs[:k] = [s for _, s in drop]
            fm = jnp.asarray(fo >= 0)
            sj = oj.free(sj, jnp.asarray(fo), jnp.asarray(fs), fm)
            sps = [o.free(s, jnp.asarray(fo), jnp.asarray(fs), fm)
                   for o, s in zip(ops_p, sps)]
        for lw, sp in zip(LOWERINGS, sps):
            _assert_state_equal(f"{variant}/{lw}", step, sj, sp)
        pool_moved |= bool(
            (np.asarray(sj.ctl)[pool_sl] != pool_ctr0).any())
    return pool_moved


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", VARIANTS)
def test_backends_bit_identical(variant):
    for seed in SEEDS:
        _replay(variant, seed)


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", VIRT_VARIANTS)
def test_backends_bit_identical_with_segment_churn(variant):
    """Small-chunk config: the va/vl segment walk grows and shrinks
    segments mid-trace, entirely inside the fused kernel."""
    pool_moved = _replay(variant, 3, cfg=GROW_CFG, sizes_menu=GROW_SIZES,
                         ops=10)
    if variant in ("va_page", "vl_page"):
        # For page-kind virtualized variants the pool only moves on
        # queue-segment grow/shrink — proof the trace exercised both
        # paths of the in-kernel walk.
        assert pool_moved, "trace never grew/shrank a queue segment"


@pytest.mark.slow
@pytest.mark.parametrize("variant", VARIANTS)
def test_backends_bit_identical_long_traces(variant):
    """Nightly CI sweep: longer traces, more seeds, both heap shapes."""
    for seed in (0, 1, 2):
        _replay(variant, seed, ops=24)
        _replay(variant, seed + 10, cfg=GROW_CFG, sizes_menu=GROW_SIZES,
                ops=24)


# ---- the fusion criterion: ONE kernel per whole transaction ---------------

from repro.kernels.ops import count_pallas_calls as _count_pallas_calls


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("lowering", LOWERINGS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_single_pallas_call_per_txn(variant, lowering):
    """backend="pallas": alloc and free each lower to exactly one
    pallas_call — the entire transaction (rank, grant, ring traffic,
    bitmap claim, va/vl segment walk) is device-fused — under BOTH the
    whole-arena and the region-blocked lowering.  The jnp oracle
    lowers to zero."""
    sizes = jnp.full(N, 64, jnp.int32)
    mask = jnp.ones(N, bool)
    offs = jnp.full(N, -1, jnp.int32)
    for backend, want in (("pallas", 1), ("jnp", 0)):
        o = Ouroboros(CFG, variant, backend, lowering)
        st = o.init()
        ja = jax.make_jaxpr(lambda s, z, m: o.alloc(s, z, m))(
            st, sizes, mask)
        jf = jax.make_jaxpr(lambda s, x, z, m: o.free(s, x, z, m))(
            st, offs, sizes, mask)
        assert _count_pallas_calls(ja) == want, (
            f"{variant}/{backend}: alloc is not a single fused kernel")
        assert _count_pallas_calls(jf) == want, (
            f"{variant}/{backend}: free is not a single fused kernel")


# ---- backend plumbing -----------------------------------------------------

def test_backend_validated():
    with pytest.raises(ValueError, match="backend"):
        Ouroboros(CFG, "page", backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        # the dispatcher itself refuses typos too — nothing silently
        # falls through to the jnp branch
        from repro.core import transactions
        o = Ouroboros(CFG, "page")
        transactions.alloc(CFG, "page", "ring", o.init(),
                           jnp.full(4, 64, jnp.int32),
                           jnp.ones(4, bool), backend="palas")


def test_lowering_validated():
    with pytest.raises(ValueError, match="lowering"):
        Ouroboros(CFG, "page", backend="pallas", lowering="bocked")
    from repro.kernels.ops import resolve_lowering
    with pytest.raises(ValueError, match="lowering"):
        resolve_lowering("bocked")
    assert resolve_lowering("whole") == "whole"
    assert resolve_lowering("blocked") == "blocked"
    assert resolve_lowering("auto") in ("whole", "blocked")


def test_backends_share_init_state():
    """A heap can switch backends mid-stream: init is backend-free."""
    oj = Ouroboros(CFG, "page", backend="jnp")
    op = Ouroboros(CFG, "page", backend="pallas")
    st = oj.init()
    sizes = jnp.full(8, 64, jnp.int32)
    mask = jnp.ones(8, bool)
    st, offs = op.alloc(st, sizes, mask)   # pallas txn on jnp-built state
    st = oj.free(st, offs, sizes, mask)    # jnp txn on pallas-built state
    st2, offs2 = op.alloc(st, sizes, mask)
    assert (np.asarray(offs2) >= 0).all()


# ---- sharded allocator: the serial single-shard oracle replay -------------
#
# DESIGN.md §9's correctness contract: a sharded transaction behaves
# exactly as if the wavefront were replayed serially through S
# independent single-arena allocators — attempt-major, shard-minor,
# still-unserved lanes retrying on neighbor shards.  The class below IS
# that replay, built from S *single-shard* jnp Ouroboros instances (the
# oracle of everything above), and the sharded implementations — jnp,
# pallas/whole, pallas/blocked — must match it bit for bit: offsets,
# failure lanes, and every per-shard arena word.

SHARDS = 4
SHARD_SEEDS = (0,)
SHARD_OPS = 5


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _oracle_alloc_math(cfg, kind, family, mem, ctl, sizes, sel, attempt):
    from repro.core import transactions
    return transactions.alloc_math(cfg, kind, family, mem, ctl,
                                   sizes, sel, attempt=attempt)


class SerialShardOracle:
    """S independent single-shard jnp allocators replayed serially."""

    def __init__(self, cfg, variant, num_shards, walk):
        from repro.core import shards
        self.S, self.walk = num_shards, walk
        self.scfg = shards.shard_config(cfg, num_shards)
        self.Ws = self.scfg.total_words
        self.ouro = Ouroboros(self.scfg, variant)          # jnp oracle
        self.states = [self.ouro.init() for _ in range(num_shards)]

    def alloc(self, sizes, mask, home):
        n = int(sizes.shape[0])
        offs = np.full(n, -1, np.int64)
        mask, home = np.asarray(mask), np.asarray(home)
        for a in range(self.walk + 1):
            for s in range(self.S):
                sel = mask & ((home + a) % self.S == s) & (offs < 0)
                st = self.states[s]
                # alloc_math directly (not Ouroboros.alloc) so the
                # walk-depth telemetry histogram attributes served
                # lanes to attempt a, as the sharded impls do; jitted
                # (attempt traced) so the chunk-claim while_loop
                # compiles inside one program, as every production
                # caller of the math does
                mem2, ctl2, local = _oracle_alloc_math(
                    self.scfg, self.ouro.kind, self.ouro.family,
                    st.mem, st.ctl, sizes, jnp.asarray(sel),
                    jnp.asarray(a, jnp.int32))
                self.states[s] = st._replace(mem=mem2, ctl=ctl2)
                local = np.asarray(local)
                offs = np.where(sel & (local >= 0),
                                s * self.Ws + local, offs)
        return offs.astype(np.int32)

    def free(self, offsets, sizes, mask):
        offsets, mask = np.asarray(offsets), np.asarray(mask)
        owner = np.where(offsets >= 0, offsets // self.Ws, -1)
        for s in range(self.S):
            sel = mask & (owner == s)
            local = np.where(sel, offsets - s * self.Ws, -1)
            self.states[s] = self.ouro.free(
                self.states[s], jnp.asarray(local.astype(np.int32)),
                sizes, jnp.asarray(sel))

    def write(self, offsets, sizes, tags):
        """Per-shard write_pattern with shard-local offsets — the
        word-for-word equivalent of the sharded global-heap write."""
        offsets = np.asarray(offsets)
        owner = np.where(offsets >= 0, offsets // self.Ws, -1)
        for s in range(self.S):
            local = np.where(owner == s, offsets - s * self.Ws,
                             -1).astype(np.int32)
            self.states[s] = self.ouro.write_pattern(
                self.states[s], jnp.asarray(local), sizes, tags)

    def check(self, offsets, sizes, tags):
        offsets = np.asarray(offsets)
        owner = np.where(offsets >= 0, offsets // self.Ws, -1)
        ok = np.zeros(offsets.shape[0], bool)
        for s in range(self.S):
            local = np.where(owner == s, offsets - s * self.Ws,
                             -1).astype(np.int32)
            ok |= np.asarray(self.ouro.check_pattern(
                self.states[s], jnp.asarray(local), sizes, tags))
        return ok

    def stacked(self):
        """(mem, ctl) stacked like shards.ShardedArena."""
        return (np.stack([np.asarray(st.mem) for st in self.states]),
                np.stack([np.asarray(st.ctl) for st in self.states]))


def _assert_matches_serial(variant, tag, serial, states):
    smem, sctl = serial.stacked()
    for (lbl, st) in states:
        np.testing.assert_array_equal(
            smem, np.asarray(st.mem),
            err_msg=f"{variant}/{lbl}: mem diverged from the serial "
                    f"single-shard oracle replay at {tag}")
        np.testing.assert_array_equal(
            sctl, np.asarray(st.ctl),
            err_msg=f"{variant}/{lbl}: ctl diverged from the serial "
                    f"single-shard oracle replay at {tag}")


def _replay_sharded(variant, seed, ops=SHARD_OPS):
    """Lockstep replay with num_shards=4: sharded jnp vs both Pallas
    lowerings vs the serial single-shard oracle replay."""
    from repro.core import shards
    rng = np.random.default_rng(seed)
    impls = [("jnp", Ouroboros(CFG, variant, num_shards=SHARDS)),
             ("pallas/whole", Ouroboros(CFG, variant, backend="pallas",
                                        lowering="whole",
                                        num_shards=SHARDS)),
             ("pallas/blocked", Ouroboros(CFG, variant,
                                          backend="pallas",
                                          lowering="blocked",
                                          num_shards=SHARDS))]
    serial = SerialShardOracle(CFG, variant, SHARDS, impls[0][1].walk)
    states = [(lbl, o.init()) for lbl, o in impls]
    home = np.asarray(shards.home_shards(N, SHARDS))  # the hashed homes

    live = []
    tagc = 0
    for step in range(ops):
        kind = rng.choice(["alloc", "free"]) if live else "alloc"
        if kind == "alloc":
            sizes = jnp.asarray(rng.choice(SIZES, N), jnp.int32)
            mask = jnp.asarray(rng.random(N) < 0.85)
            want = serial.alloc(sizes, mask, home)
            new = []
            for (lbl, o), (_, st) in zip(impls, states):
                st, offs = o.alloc(st, sizes, mask)
                np.testing.assert_array_equal(
                    want, np.asarray(offs),
                    err_msg=f"{variant}/{lbl}: sharded offsets diverged "
                            f"from the serial replay at op {step}")
                new.append((lbl, st))
            states = new
            # write/check through the GLOBAL heap view: the sharded
            # write_pattern must land the same words as the per-shard
            # writes of the serial oracle
            tags = jnp.arange(tagc, tagc + N, dtype=jnp.int32)
            tagc += N
            so = jnp.asarray(want, jnp.int32)
            serial.write(want, sizes, tags)
            states = [(lbl, o.write_pattern(st, so, sizes, tags))
                      for (lbl, o), (_, st) in zip(impls, states)]
            cj = serial.check(want, sizes, tags)
            for (lbl, o), (_, st) in zip(impls, states):
                cp = np.asarray(o.check_pattern(st, so, sizes, tags))
                np.testing.assert_array_equal(
                    cj, cp, err_msg=f"{variant}/{lbl}: integrity "
                                    f"verdicts diverged at op {step}")
            live.extend((int(o), int(s))
                        for o, s in zip(want, np.asarray(sizes))
                        if o >= 0)
        else:
            k = min(len(live), int(rng.integers(1, N + 1)))
            pick = rng.choice(len(live), k, replace=False)
            drop = [live[i] for i in pick]
            live = [x for i, x in enumerate(live) if i not in set(pick)]
            fo = np.full(N, -1, np.int32)
            fs = np.zeros(N, np.int32)
            fo[:k] = [o for o, _ in drop]
            fs[:k] = [s for _, s in drop]
            fm = jnp.asarray(fo >= 0)
            serial.free(fo, jnp.asarray(fs), fm)
            states = [(lbl, o.free(st, jnp.asarray(fo), jnp.asarray(fs),
                                   fm))
                      for (lbl, o), (_, st) in zip(impls, states)]
        _assert_matches_serial(variant, f"op {step}", serial, states)
    # homes must actually spread over the shards, or the walk schedule
    # was never multi-shard to begin with
    assert len(set(home.tolist())) > 1


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", VARIANTS)
def test_sharded_bit_identical_to_serial_oracle(variant):
    """num_shards=4: sharded jnp, whole, and blocked all replay the
    serial single-shard oracle schedule bit for bit (offsets, failure
    lanes, every word of every shard)."""
    for seed in SHARD_SEEDS:
        _replay_sharded(variant, seed)


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", ("page", "va_page", "vl_chunk"))
def test_sharded_pinned_fast_path_matches_serial(variant):
    """Static shard_hint + overflow_walk=0: the pinned fast path (only
    the hinted shard enters the kernel) stays on the serial-replay
    trajectory with a constant home and no walk."""
    from repro.core import shards
    hint = 2
    # 16 chunks per shard: enough for vl_chunk's init-time queue
    # segments (one per class) to leave data chunks in the pool
    pin_cfg = HeapConfig(total_bytes=1 << 17, chunk_bytes=1 << 11,
                         min_page_bytes=16)
    impls = [("jnp", Ouroboros(pin_cfg, variant, num_shards=SHARDS,
                               overflow_walk=0)),
             ("pallas/whole", Ouroboros(pin_cfg, variant,
                                        backend="pallas",
                                        lowering="whole",
                                        num_shards=SHARDS,
                                        overflow_walk=0)),
             ("pallas/blocked", Ouroboros(pin_cfg, variant,
                                          backend="pallas",
                                          lowering="blocked",
                                          num_shards=SHARDS,
                                          overflow_walk=0))]
    serial = SerialShardOracle(pin_cfg, variant, SHARDS, walk=0)
    states = [(lbl, o.init()) for lbl, o in impls]
    home = np.full(N, hint, np.int64)
    sizes = jnp.asarray([64, 256, 64, 1000] * (N // 4), jnp.int32)
    mask = jnp.ones(N, bool)

    want = serial.alloc(sizes, mask, home)
    granted = want >= 0
    # partial grants are fine (per-shard inventories are small) — the
    # contract under test is serial-replay equality + shard residency
    assert granted.any()
    Ws = shards.shard_config(pin_cfg, SHARDS).total_words
    assert set((want[granted] // Ws).tolist()) == {hint}, \
        "pinned grants must come from the hinted shard"
    new = []
    for (lbl, o), (_, st) in zip(impls, states):
        st, offs = o.alloc(st, sizes, mask, shard_hint=hint)
        np.testing.assert_array_equal(want, np.asarray(offs),
                                      err_msg=f"{variant}/{lbl}")
        new.append((lbl, st))
    states = new
    _assert_matches_serial(variant, "pinned-alloc", serial, states)

    serial.free(want, sizes, mask)
    states = [(lbl, o.free(st, jnp.asarray(want), sizes, mask,
                           shard_hint=hint))
              for (lbl, o), (_, st) in zip(impls, states)]
    _assert_matches_serial(variant, "pinned-free", serial, states)


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("lowering", LOWERINGS)
@pytest.mark.parametrize("variant", ("page", "chunk", "va_page",
                                     "vl_chunk"))
def test_sharded_single_pallas_call_per_txn(variant, lowering):
    """The one-kernel property survives sharding: with num_shards=4 the
    (attempt, shard) schedule rides the grid of ONE pallas_call for
    alloc and free, under BOTH lowerings (jnp still lowers to zero)."""
    sizes = jnp.full(N, 64, jnp.int32)
    mask = jnp.ones(N, bool)
    offs = jnp.full(N, -1, jnp.int32)
    for backend, want in (("pallas", 1), ("jnp", 0)):
        o = Ouroboros(CFG, variant, backend, lowering,
                      num_shards=SHARDS)
        st = o.init()
        ja = jax.make_jaxpr(lambda s, z, m: o.alloc(s, z, m))(
            st, sizes, mask)
        jf = jax.make_jaxpr(lambda s, x, z, m: o.free(s, x, z, m))(
            st, offs, sizes, mask)
        assert _count_pallas_calls(ja) == want, (
            f"{variant}/{backend}/shards: alloc is not a single fused "
            f"kernel")
        assert _count_pallas_calls(jf) == want, (
            f"{variant}/{backend}/shards: free is not a single fused "
            f"kernel")


def test_shard_knobs_validated():
    from repro.core import shards
    with pytest.raises(ValueError, match="num_chunks"):
        # 32 chunks don't divide by 5
        Ouroboros(CFG, "page", num_shards=5)
    with pytest.raises(ValueError, match="overflow_walk"):
        Ouroboros(CFG, "page", num_shards=4, overflow_walk=-1)
    with pytest.raises(ValueError, match="overflow_walk"):
        # an ignored knob must not be silently accepted
        Ouroboros(CFG, "page", overflow_walk=2)
    with pytest.raises(ValueError, match="shard_hint"):
        o = Ouroboros(CFG, "page")
        o.alloc(o.init(), jnp.full(4, 64, jnp.int32),
                jnp.ones(4, bool), shard_hint=0)
    with pytest.raises(ValueError, match="shard_hint"):
        shards.home_shards(8, 4, jnp.zeros(5, jnp.int32))
    # walk resolution: None = all neighbors, ints clamp to S-1
    assert shards.resolve_walk(4, None) == 3
    assert shards.resolve_walk(4, 99) == 3
    assert shards.resolve_walk(4, 1) == 1


def test_numpy_integer_shard_hint_pins_like_python_int():
    """np.int32/np.int64 hints (e.g. an element of a hints array) must
    behave exactly like a Python int — including taking the pinned
    fast path when the walk is off."""
    from repro.core import shards
    o = Ouroboros(CFG, "page", num_shards=SHARDS, overflow_walk=0)
    sizes = jnp.full(4, 64, jnp.int32)
    mask = jnp.ones(4, bool)
    st_py, offs_py = o.alloc(o.init(), sizes, mask, shard_hint=2)
    st_np, offs_np = o.alloc(o.init(), sizes, mask,
                             shard_hint=np.int32(2))
    np.testing.assert_array_equal(np.asarray(offs_py),
                                  np.asarray(offs_np))
    _assert_state_equal("page/np-hint", "pinned", st_py, st_np)
    assert shards.static_hint(np.int64(3)) == 3
    assert shards.static_hint(3) == 3
    assert shards.static_hint(None) is None
    assert shards.static_hint(jnp.zeros(4, jnp.int32)) is None


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", ("page", "va_page", "vl_chunk"))
def test_midstream_backend_switch_stays_on_oracle_trajectory(variant):
    """Replaying a trace while hopping jnp→whole→blocked after every
    op lands bit-identically on the pure-jnp trajectory (the
    ouroboros.py promise that shared init state lets a heap switch
    backends — now including the kernel lowering)."""
    oj = Ouroboros(CFG, variant, backend="jnp")
    ow = Ouroboros(CFG, variant, backend="pallas", lowering="whole")
    ob = Ouroboros(CFG, variant, backend="pallas", lowering="blocked")
    rng = np.random.default_rng(7)
    ref, mix = oj.init(), oj.init()  # distinct buffers: alloc donates
    hop = [oj, ow, ob, ow, ob]  # jnp→whole→blocked→whole→blocked…
    tagc = 0
    live = []
    for step in range(len(hop) + 1):
        o = hop[step % len(hop)]
        if live and rng.random() < 0.4:
            k = min(len(live), N)
            fo = np.full(N, -1, np.int32)
            fs = np.zeros(N, np.int32)
            fo[:k] = [x[0] for x in live[:k]]
            fs[:k] = [x[1] for x in live[:k]]
            live = live[k:]
            fm = jnp.asarray(fo >= 0)
            ref = oj.free(ref, jnp.asarray(fo), jnp.asarray(fs), fm)
            mix = o.free(mix, jnp.asarray(fo), jnp.asarray(fs), fm)
        else:
            sizes = jnp.asarray(rng.choice(SIZES, N), jnp.int32)
            mask = jnp.asarray(rng.random(N) < 0.85)
            ref, offr = oj.alloc(ref, sizes, mask)
            mix, offm = o.alloc(mix, sizes, mask)
            np.testing.assert_array_equal(np.asarray(offr),
                                          np.asarray(offm))
            tags = jnp.arange(tagc, tagc + N, dtype=jnp.int32)
            tagc += N
            so = jnp.asarray(np.asarray(offr), jnp.int32)
            ref = oj.write_pattern(ref, so, sizes, tags)
            mix = o.write_pattern(mix, so, sizes, tags)
            live.extend((int(x), int(s)) for x, s in
                        zip(np.asarray(offr), np.asarray(sizes)) if x >= 0)
        _assert_state_equal(variant, f"switch-{step}", ref, mix)
