"""Differential harness: the Pallas fused-transaction backend vs the
jnp reference oracle, on randomized alloc/free/write/check traces.

For every variant the same trace is replayed through
``Ouroboros(cfg, variant, backend="jnp")`` and ``backend="pallas"``
(interpret mode on CPU — the compiled path's exact semantics) and the
two executions must be **bit-identical** at every step:

  - granted offsets and failure masks (−1 lanes)
  - ``check_pattern`` integrity verdicts
  - the full allocator state pytree (heap words, ring stores,
    front/back counters, virtual-queue directories/chains, chunk
    bitmaps and free counts, pool)

This is the safety net the ISSUE calls for: any rewrite of the hot
path must keep the two backends in lockstep, so the kernels can evolve
while the jnp path stays the oracle.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
SIZES = [16, 24, 100, 256, 1000, 2048, 8192]  # 8192 > chunk → must fail
N = 16       # fixed lane width so every transaction reuses one jit cache
OPS = 8
SEEDS = (0, 1)


def _assert_state_equal(variant, step, sj, sp):
    la, lb = jax.tree.leaves(sj), jax.tree.leaves(sp)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{variant}: state diverged after op {step}")


def _replay(variant, seed):
    rng = np.random.default_rng(seed)
    oj = Ouroboros(CFG, variant, backend="jnp")
    op = Ouroboros(CFG, variant, backend="pallas")
    sj, sp = oj.init(), op.init()
    _assert_state_equal(variant, "init", sj, sp)

    live = []  # (offset, size) granted and not yet freed
    tagc = 0
    for step in range(OPS):
        kind = rng.choice(["alloc", "free"]) if live else "alloc"
        if kind == "alloc":
            sizes = jnp.asarray(rng.choice(SIZES, N), jnp.int32)
            mask = jnp.asarray(rng.random(N) < 0.85)
            sj, offj = oj.alloc(sj, sizes, mask)
            sp, offp = op.alloc(sp, sizes, mask)
            offj, offp = np.asarray(offj), np.asarray(offp)
            np.testing.assert_array_equal(
                offj, offp,
                err_msg=f"{variant}: offsets/failure masks diverged "
                        f"at op {step}")
            tags = jnp.arange(tagc, tagc + N, dtype=jnp.int32)
            tagc += N
            so = jnp.asarray(offj, jnp.int32)
            sj = oj.write_pattern(sj, so, sizes, tags)
            sp = op.write_pattern(sp, so, sizes, tags)
            cj = np.asarray(oj.check_pattern(sj, so, sizes, tags))
            cp = np.asarray(op.check_pattern(sp, so, sizes, tags))
            np.testing.assert_array_equal(
                cj, cp, err_msg=f"{variant}: integrity verdicts "
                                f"diverged at op {step}")
            live.extend((int(o), int(s))
                        for o, s in zip(offj, np.asarray(sizes)) if o >= 0)
        else:
            k = min(len(live), int(rng.integers(1, N + 1)))
            pick = rng.choice(len(live), k, replace=False)
            drop = [live[i] for i in pick]
            live = [x for i, x in enumerate(live) if i not in set(pick)]
            fo = np.full(N, -1, np.int32)
            fs = np.zeros(N, np.int32)
            fo[:k] = [o for o, _ in drop]
            fs[:k] = [s for _, s in drop]
            fm = jnp.asarray(fo >= 0)
            sj = oj.free(sj, jnp.asarray(fo), jnp.asarray(fs), fm)
            sp = op.free(sp, jnp.asarray(fo), jnp.asarray(fs), fm)
        _assert_state_equal(variant, step, sj, sp)


@pytest.mark.parametrize("variant", VARIANTS)
def test_backends_bit_identical(variant):
    for seed in SEEDS:
        _replay(variant, seed)


def test_backend_validated():
    with pytest.raises(ValueError, match="backend"):
        Ouroboros(CFG, "page", backend="cuda")


def test_backends_share_init_state():
    """A heap can switch backends mid-stream: init is backend-free."""
    oj = Ouroboros(CFG, "page", backend="jnp")
    op = Ouroboros(CFG, "page", backend="pallas")
    st = oj.init()
    sizes = jnp.full(8, 64, jnp.int32)
    mask = jnp.ones(8, bool)
    st, offs = op.alloc(st, sizes, mask)   # pallas txn on jnp-built state
    st = oj.free(st, offs, sizes, mask)    # jnp txn on pallas-built state
    st2, offs2 = op.alloc(st, sizes, mask)
    assert (np.asarray(offs2) >= 0).all()
