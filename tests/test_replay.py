"""Traffic-replay harness tests (serve/replay.py, DESIGN.md §13).

The replay subsystem doubles as the serving engine's hardest
correctness net, so this file is where the zoo-wide guarantees live:

- seeded traces are **deterministic** (same seed → identical trace,
  different seed → different stream) and respect the engine's bounds;
- **cancellation** (the client-abandonment path) handles all three
  uid states — waiting, active, retired — and frees every page the
  request ever held (KV + modality aux) back through the allocator;
- **parity**: the same trace replays token-for-token identically on
  the host decode loop and the fused mega-step, including under bursty
  load and mid-stream abandonment, with end-state conservation;
- **no family untested**: a replay smoke runs over every arch in the
  zoo (tiny geometries), exercising the per-modality page policy —
  SSM state and MoE expert-buffer pages through the same arena as KV.

Marker ``replay`` (conftest.py): the forced-blocked CI job runs
``pytest -m replay``; the nightly job adds the two-scenario benchmark
smoke (``benchmarks/run.py --quick --fig fig9_replay``).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import ALL_ARCHS
from repro.serve.replay import (SCENARIOS, Scenario, engine_factory,
                                assert_conserved, generate_trace,
                                replay, replay_pair)

pytestmark = pytest.mark.replay


# ---- trace generation -----------------------------------------------------

def test_trace_determinism():
    """Same (scenario, seed) → identical trace; different seed →
    different stream; every scenario in the zoo is covered."""
    for name, sc in SCENARIOS.items():
        a = generate_trace(sc, seed=13, vocab_size=128)
        b = generate_trace(sc, seed=13, vocab_size=128)
        assert a == b, f"scenario {name} not deterministic"
        c = generate_trace(sc, seed=14, vocab_size=128)
        assert a != c, f"scenario {name} ignores its seed"


def test_trace_respects_engine_bounds():
    sc = SCENARIOS["bursty"]
    items = generate_trace(sc, seed=0, vocab_size=64, max_seq=48,
                           max_new_cap=8)
    assert len(items) == sc.n_requests
    assert items == sorted(items, key=lambda it: it.step)
    for it in items:
        assert 1 <= len(it.prompt) and it.max_new >= 1
        assert it.max_new <= 8
        assert len(it.prompt) + it.max_new <= 48
        assert all(2 <= t < 64 for t in it.prompt)


def test_abandon_scenario_schedules_cancels():
    items = generate_trace(SCENARIOS["abandon"], seed=1, vocab_size=64)
    cancels = [it for it in items if it.cancel_step is not None]
    assert cancels, "abandon scenario generated no abandonments"
    for it in cancels:
        assert it.cancel_step >= it.step


def test_scenario_validation():
    with pytest.raises(ValueError, match="arrival"):
        Scenario("bad", arrival="uniform")
    with pytest.raises(ValueError, match="abandon_frac"):
        Scenario("bad", abandon_frac=1.5)


# ---- cancellation: the client-abandonment engine path ---------------------

def _mini_trace(vocab, n=3, max_new=4):
    rng = np.random.default_rng(0)
    return [rng.integers(2, vocab, 6) for _ in range(n)], max_new


def test_cancel_waiting_active_retired():
    """Regression for the three uid states: a uid still in the waiting
    queue is removed before touching a slot; an active uid frees its
    pages; a retired (or never-submitted) uid is a no-op returning
    False — never a KeyError."""
    cfg, make = engine_factory("qwen2-0.5b", max_batch=2)
    eng = make(mega=False)
    prompts, max_new = _mini_trace(cfg.vocab_size, n=4)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]

    # 2 slots: after one step uids[0:2] are active, uids[2:] wait
    eng.step()
    active = {r.uid for r in eng.slot_req if r is not None}
    waiting = [r.uid for r in eng.waiting]
    assert len(active) == 2 and len(waiting) == 2

    assert eng.cancel(waiting[0]) is True          # waiting-queue path
    assert waiting[0] not in [r.uid for r in eng.waiting]
    live_before = eng.stats["allocs"] - eng.stats["frees"]
    victim = sorted(active)[0]
    assert eng.cancel(victim) is True              # active-slot path
    live_after = eng.stats["allocs"] - eng.stats["frees"]
    assert live_after < live_before, "cancel freed no pages"
    assert victim not in {r.uid for r in eng.slot_req if r is not None}
    assert eng.cancel(victim) is False             # already cancelled
    assert eng.cancel(10_000) is False             # never submitted

    done = eng.run_until_done(500)
    retired = done[0].uid
    assert eng.cancel(retired) is False            # retired: no-op
    assert {r.uid for r in done} == set(uids) - {waiting[0], victim}
    assert_conserved(eng)
    assert eng.stats["cancels"] == 2


@pytest.mark.parametrize("mega", [False, True], ids=["host", "mega"])
def test_abandonment_frees_all_pages(mega):
    """The headline conservation property: after an abandonment-heavy
    replay drains, every page ever granted — KV and modality aux alike
    — went back through the allocator (allocs == frees), no slot holds
    pages, and the device page table is all holes."""
    cfg, make = engine_factory("mamba2-780m")   # aux pages > 0
    eng = make(mega=mega)
    assert eng.aux_pages > 0, "SSM config should carry state pages"
    trace = generate_trace(SCENARIOS["abandon"], seed=5,
                           vocab_size=cfg.vocab_size)
    r = replay(eng, trace, scenario="abandon")
    assert r.cancelled, "abandon trace cancelled nothing"
    assert_conserved(eng)
    assert eng.stats["cancels"] == len(r.cancelled)


def test_bursty_parity_mega_vs_host():
    """Token-for-token parity between the host decode loop and the
    fused mega-step under a bursty trace that overruns max_batch (so
    the waiting queue and the allocator churn together)."""
    cfg, make = engine_factory("qwen2-0.5b")
    trace = generate_trace(SCENARIOS["bursty"], seed=11,
                           vocab_size=cfg.vocab_size)
    assert len(trace) > 3 * 2, "burst should overrun the batch"
    host, mega = replay_pair(make(mega=False), make(mega=True), trace,
                             scenario="bursty")
    assert host.tokens == mega.tokens and host.tokens
    assert host.queue_wait == mega.queue_wait


def test_abandon_parity_with_aux_pages():
    """Parity holds through mid-stream cancels on a config whose slots
    hold modality aux pages (hybrid RG-LRU state)."""
    cfg, make = engine_factory("recurrentgemma-9b")
    eng_h, eng_m = make(mega=False), make(mega=True)
    assert eng_h.aux_pages > 0
    trace = generate_trace(SCENARIOS["abandon"], seed=7,
                           vocab_size=cfg.vocab_size)
    host, mega = replay_pair(eng_h, eng_m, trace, scenario="abandon")
    assert host.cancelled == mega.cancelled and host.cancelled


def test_shard_parity():
    """The other parity axis: shards 1 vs 4 on the same trace and
    decode mode must agree token-for-token (hashed home-shard routing
    is an allocator-internal concern — DESIGN.md §9)."""
    cfg, make = engine_factory("qwen2-0.5b")
    trace = generate_trace(SCENARIOS["steady"], seed=2,
                           vocab_size=cfg.vocab_size)
    one, four = replay_pair(make(mega=False, num_shards=1),
                            make(mega=False, num_shards=4), trace,
                            scenario="steady")
    assert one.tokens == four.tokens and one.tokens


# ---- the zoo: no model family untested ------------------------------------

_SMOKE = dataclasses.replace(SCENARIOS["abandon"], n_requests=4,
                             out_lens=(2, 5), abandon_frac=0.4)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_replay_smoke_every_config(arch):
    """Every arch in the zoo replays a short abandonment trace on the
    host loop with conservation asserted — the per-modality page
    policy (SSM state, MoE expert buffers, plain KV) all route through
    the same Ouroboros arena, and no family is ever untested again."""
    cfg, make = engine_factory(arch, max_batch=2)
    eng = make(mega=False)
    trace = generate_trace(_SMOKE, seed=3, vocab_size=cfg.vocab_size)
    r = replay(eng, trace, scenario="smoke")
    assert len(r.tokens) + len(r.cancelled) == len(trace)
    assert all(ts for ts in r.tokens.values())
    assert_conserved(eng)


def test_modality_page_quota_families():
    """The quota helper behind the aux policy: zero for pure-attention
    families, positive for state-holding ones, and exact page-count
    arithmetic (ceil of state bytes over the page size)."""
    from repro.configs import get_arch
    from repro.paged.kv_cache import modality_page_quota

    quota = {a: modality_page_quota(get_arch(a).smoke())
             for a in ALL_ARCHS}
    assert quota["qwen2-0.5b"] == 0 and quota["qwen2-vl-2b"] == 0
    assert quota["seamless-m4t-large-v2"] == 0
    assert quota["mamba2-780m"] > 0 and quota["recurrentgemma-9b"] > 0
    assert quota["mixtral-8x7b"] > 0 and quota["phi3.5-moe-42b-a6.6b"] > 0
    # exactness on one family: mixtral's expert buffer is
    # layers · top_k · d_ff bf16 elements
    cfg = get_arch("mixtral-8x7b").smoke()
    bytes_ = cfg.num_layers * cfg.num_experts_per_tok * cfg.d_ff * 2
    assert quota["mixtral-8x7b"] == -(-bytes_ // 256)


# ---- telemetry + BENCH_serve.json schema ----------------------------------

def test_replay_summary_is_schema_complete():
    """A ReplayResult.summary() cell carries every telemetry key the
    BENCH_serve.json replay schema requires — the benchmark can never
    append a record the validator rejects."""
    from benchmarks.common import REPLAY_CELL_KEYS

    cfg, make = engine_factory("qwen2-0.5b")
    trace = generate_trace(SCENARIOS["steady"], seed=0,
                           vocab_size=cfg.vocab_size)
    s = replay(make(mega=False), trace, scenario="steady").summary()
    assert all(k in s for k in REPLAY_CELL_KEYS)
    assert s["tick_ms_p99"] >= s["tick_ms_p50"] >= 0.0
    assert s["queue_wait_p99"] >= s["queue_wait_p50"] >= 0.0
    assert s["completed"] + s["cancelled"] == s["requests"]


def _replay_cell():
    from benchmarks.common import REPLAY_CELL_KEYS
    return {k: 0 for k in REPLAY_CELL_KEYS}


def test_validate_serve_record():
    """The benchmarks/common.py schema validator: legacy records
    (no ``record`` key) pass as kind "serve"; replay records need the
    full telemetry cell; every violation raises with the offending
    key named."""
    from benchmarks.common import validate_serve_record as v

    legacy = {"platform": "cpu", "git_sha": "abc", "quick": True,
              "cells": {"host/jnp": {"tokens": 1}}}
    assert v(legacy) == "serve"
    assert v(dict(legacy, record="serve")) == "serve"
    assert v(dict(legacy, record="replay",
                  cells={"a/b/c/host": _replay_cell()})) == "replay"

    with pytest.raises(ValueError, match="kind"):
        v(dict(legacy, record="perf"))
    with pytest.raises(ValueError, match="git_sha"):
        v({"platform": "cpu", "cells": {"x": {}}})
    with pytest.raises(ValueError, match="cells"):
        v(dict(legacy, cells={}))
    with pytest.raises(ValueError, match="tick_ms_p99"):
        bad = _replay_cell()
        del bad["tick_ms_p99"]
        v(dict(legacy, record="replay", cells={"a": bad}))
    with pytest.raises(ValueError, match="dict"):
        v(["not", "a", "record"])


def test_bench_serve_json_is_schema_valid():
    """Every record already in the repo's BENCH_serve.json trajectory
    validates — the append-only file can never accumulate a record the
    schema helpers would reject."""
    import pathlib

    from benchmarks.common import load_runs, validate_serve_record

    path = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_serve.json"
    runs = load_runs(str(path))
    assert runs, "BENCH_serve.json lost its trajectory"
    kinds = [validate_serve_record(r) for r in runs]
    assert kinds[0] == "serve"      # the original fig8 record survives


def test_append_serve_record_is_append_only(tmp_path):
    """append_serve_record validates before writing, keeps prior runs,
    and refuses to touch an unparseable trajectory file."""
    from benchmarks.common import append_serve_record, load_runs

    p = str(tmp_path / "BENCH_serve.json")
    rec = {"platform": "cpu", "git_sha": "abc", "quick": True,
           "record": "replay",
           "cells": {"dense/q/steady/host": _replay_cell()}}
    assert append_serve_record(p, rec) == 1
    assert append_serve_record(p, rec) == 2
    assert [r["record"] for r in load_runs(p)] == ["replay", "replay"]

    with pytest.raises(ValueError):              # invalid: not written
        append_serve_record(p, {"platform": "cpu"})
    assert len(load_runs(p)) == 2

    with open(p, "w") as f:
        f.write("{corrupt")
    with pytest.raises(SystemExit, match="refusing"):
        append_serve_record(p, rec)
