"""Observability layer tests (obs/, DESIGN.md §14).

Three surfaces under test:

1. **In-kernel telemetry** — the ctl-block accumulator region
   (``ArenaLayout.tele_fields()``) is advanced inside the existing
   single transaction ``pallas_call``.  The matrix here replays the
   same randomized trace through the jnp oracle and BOTH Pallas
   lowerings (whole / blocked), single-arena and ``num_shards=4``, and
   requires the drained telemetry words to be **bit-identical** across
   implementations AND to reconcile against host-side bookkeeping of
   the trace (granted/freed/failed lane counts).  The one-kernel fusion
   criterion is re-asserted on the jaxpr with telemetry active — the
   accumulators must not cost a launch.

2. **Metrics registry** (obs/metrics.py) — labelled counters / gauges /
   histograms, Prometheus text exposition (schema-checked by
   ``validate_exposition``) and JSON export, declaration hygiene.

3. **Trace spans** (obs/trace.py) — Chrome ``trace_event`` documents,
   the engine span taxonomy, the compile-vs-steady tick split that
   ``validate_trace(..., require_phases=True)`` enforces, and the NULL
   no-op tracer.  Plus ``StepMonitor`` publishing through a registry
   (ft/runtime.py), so training and serving export through one funnel.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros
from repro.core import arena
from repro.kernels.ops import count_pallas_calls
from repro.obs import telemetry
from repro.obs.metrics import (MetricsRegistry, validate_exposition)
from repro.obs.trace import (NULL, PHASES, Tracer, validate_trace)

pytestmark = pytest.mark.obs

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
# menu spans every class plus an over-chunk size that must fail AND an
# over-large size (class == num_classes) that must count as neither an
# attempt nor a failure
SIZES = [16, 24, 100, 256, 1000, 2048, 8192]
N = 16
SHARDS = 4

IMPLS = (("jnp", dict(backend="jnp")),
         ("whole", dict(backend="pallas", lowering="whole")),
         ("blocked", dict(backend="pallas", lowering="blocked")))


def _cls(size_bytes):
    """Host size→class that maps oversized to num_classes instead of
    raising (mirrors ``size_to_class_device``)."""
    import math
    sz = max(int(size_bytes), CFG.min_page_bytes)
    return (math.ceil(math.log2(sz))
            - int(math.log2(CFG.min_page_bytes)))


def _drain(ouro, state):
    """Decoded telemetry dict for a single or sharded allocator."""
    lay = ouro.layout
    shard_lay = getattr(lay, "shard", lay)
    return telemetry.decode(shard_lay, np.asarray(state.ctl))


def _replay_with_books(ouro, seed=0, ops=8):
    """Replay a short trace; return (decoded telemetry, host books).

    The books count what the trace observably did — granted lanes,
    freed lanes, failed *attempts* (masked-in, class < C, offset < 0)
    — from the transaction outputs alone, implementation-blind.
    """
    rng = np.random.default_rng(seed)
    C = CFG.num_classes
    st = ouro.init()
    books = {"granted": np.zeros(C, np.int64),
             "freed": np.zeros(C, np.int64),
             "failed_min": np.zeros(C, np.int64)}
    live = []
    for _ in range(ops):
        kind = rng.choice(["alloc", "free"]) if live else "alloc"
        if kind == "alloc":
            sizes = rng.choice(SIZES, N).astype(np.int32)
            mask = rng.random(N) < 0.85
            st, offs = ouro.alloc(st, jnp.asarray(sizes),
                                  jnp.asarray(mask))
            offs = np.asarray(offs)
            for sz, m, off in zip(sizes, mask, offs):
                c = _cls(sz)
                if not m or c >= C:
                    continue
                if off >= 0:
                    books["granted"][c] += 1
                    live.append((int(off), int(sz)))
                else:
                    # at least one failed attempt; under sharding each
                    # visited shard adds one, so this is a lower bound
                    books["failed_min"][c] += 1
        else:
            k = min(len(live), N)
            picks = [live.pop() for _ in range(k)]
            offs = np.full(N, -1, np.int32)
            sizes = np.full(N, 16, np.int32)
            for i, (off, sz) in enumerate(picks):
                offs[i], sizes[i] = off, sz
            mask = offs >= 0
            st = ouro.free(st, jnp.asarray(offs), jnp.asarray(sizes),
                           jnp.asarray(mask))
            for off, sz in picks:
                books["freed"][_cls(sz)] += 1
    return _drain(ouro, st), books


@pytest.mark.parametrize("num_shards", [1, SHARDS])
@pytest.mark.compiled_lowering
def test_telemetry_bit_identical_across_impls(num_shards):
    """The telemetry region is part of the bit-parity contract: the
    same trace drains to word-identical accumulators from the jnp
    oracle and both Pallas lowerings, single-arena and sharded."""
    kw = {} if num_shards == 1 else {"num_shards": num_shards}
    drained = {}
    for name, impl_kw in IMPLS:
        ouro = Ouroboros(CFG, "page", **impl_kw, **kw)
        drained[name], _ = _replay_with_books(ouro, seed=0)
    ref = drained["jnp"]
    for name in ("whole", "blocked"):
        for field, want in ref.items():
            np.testing.assert_array_equal(
                want, drained[name][field],
                err_msg=f"telemetry {field} diverged on {name} "
                        f"(shards={num_shards})")


@pytest.mark.parametrize("num_shards", [1, SHARDS])
def test_telemetry_reconciles_with_host_books(num_shards):
    """Drained words match implementation-blind host bookkeeping of
    the same trace: t_alloc == granted lanes per class, t_free ==
    freed, t_fail ≥ failed attempts (== for one shard; per-visit under
    sharding), walk bins sum to total grants, and oversized lanes
    (class == num_classes) never count."""
    kw = {} if num_shards == 1 else {"num_shards": num_shards}
    ouro = Ouroboros(CFG, "page", backend="jnp", **kw)
    tele, books = _replay_with_books(ouro, seed=0)
    # sharded decode keeps a leading shard axis; totals sum it away
    t_alloc = np.asarray(tele["t_alloc"]).reshape(-1, CFG.num_classes)
    t_free = np.asarray(tele["t_free"]).reshape(-1, CFG.num_classes)
    t_fail = np.asarray(tele["t_fail"]).reshape(-1, CFG.num_classes)
    np.testing.assert_array_equal(t_alloc.sum(0), books["granted"])
    np.testing.assert_array_equal(t_free.sum(0), books["freed"])
    if num_shards == 1:
        np.testing.assert_array_equal(t_fail.sum(0),
                                      books["failed_min"])
        # single-arena traffic never walks past bin 0
        walk = np.asarray(tele["t_walk"]).reshape(-1)
        assert walk[1:].sum() == 0
    else:
        assert np.all(t_fail.sum(0) >= books["failed_min"])
    assert int(np.asarray(tele["t_walk"]).sum()) == \
        int(books["granted"].sum())
    assert int(np.asarray(tele["t_grow"]).sum()) >= 0


def test_telemetry_segment_churn_counts_grow_shrink():
    """With tiny chunks the virtualized queues grow and reclaim
    segments mid-trace; t_grow/t_shrink mirror the pool counters the
    core already maintains (and pool wraps count full ring turns)."""
    cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=64,
                     min_page_bytes=16)
    ouro = Ouroboros(cfg, "vl_page", backend="jnp")
    lay = ouro.layout
    st = ouro.init()
    ctl0 = np.asarray(st.ctl).copy()  # init pre-claims chunks
    rng = np.random.default_rng(2)
    live = []
    for _ in range(10):
        sizes = rng.choice([16, 32, 64], N).astype(np.int32)
        st, offs = ouro.alloc(st, jnp.asarray(sizes),
                              jnp.ones(N, bool))
        offs = np.asarray(offs)
        live += [(int(o), int(s)) for o, s in zip(offs, sizes)
                 if o >= 0]
        if len(live) > N:
            picks = [live.pop() for _ in range(N)]
            offs_f = np.asarray([o for o, _ in picks], np.int32)
            sizes_f = np.asarray([s for _, s in picks], np.int32)
            st = ouro.free(st, jnp.asarray(offs_f),
                           jnp.asarray(sizes_f), jnp.ones(N, bool))
    ctl = np.asarray(st.ctl)
    tele = telemetry.decode(lay, ctl)
    assert int(tele["t_grow"]) == (int(ctl[lay.off_pool_front])
                                   - int(ctl0[lay.off_pool_front]))
    assert int(tele["t_shrink"]) == (int(ctl[lay.off_pool_back])
                                     - int(ctl0[lay.off_pool_back]))
    assert int(tele["t_grow"]) > 0
    tot = telemetry.totals(lay, ctl)
    assert tot["t_grow"] == int(tele["t_grow"])


@pytest.mark.parametrize("lowering", ["whole", "blocked"])
@pytest.mark.parametrize("num_shards", [1, SHARDS])
@pytest.mark.compiled_lowering
def test_single_pallas_call_with_telemetry(lowering, num_shards):
    """The accumulators ride inside the existing kernel: with
    telemetry active (it always is), alloc and free still lower to
    exactly ONE pallas_call, both lowerings, sharded or not."""
    kw = {} if num_shards == 1 else {"num_shards": num_shards}
    o = Ouroboros(CFG, "page", backend="pallas", lowering=lowering,
                  **kw)
    st = o.init()
    sizes = jnp.full(N, 64, jnp.int32)
    mask = jnp.ones(N, bool)
    offs = jnp.zeros(N, jnp.int32)
    ja = jax.make_jaxpr(lambda s, z, m: o.alloc(s, z, m))(
        st, sizes, mask)
    jf = jax.make_jaxpr(lambda s, x, z, m: o.free(s, x, z, m))(
        st, offs, sizes, mask)
    assert count_pallas_calls(ja) == 1, (
        f"{lowering}/shards={num_shards}: telemetry cost alloc a launch")
    assert count_pallas_calls(jf) == 1, (
        f"{lowering}/shards={num_shards}: telemetry cost free a launch")


def test_tele_fields_cover_region_exactly():
    """The field table tiles [core_ctl_words, ctl_words) with no gaps
    or overlaps — what decode() and DESIGN.md §14 both render."""
    lay = arena.layout(CFG, "page", "ring")
    fields = lay.tele_fields()
    cursor = lay.core_ctl_words
    for name, off, w in fields:
        assert off == cursor, f"{name} leaves a gap at {cursor}"
        cursor = off + w
    assert cursor == lay.ctl_words
    assert lay.tele_words == lay.ctl_words - lay.core_ctl_words


# ---- metrics registry ------------------------------------------------------

def test_metrics_counter_gauge_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "a counter",
                    labelnames=("shard",))
    c.labels(shard=0).inc()
    c.labels(shard=0).inc(2)
    c.labels(shard=1).set(7)  # re-publishing a device total
    reg.gauge("repro_test_waiting", "a gauge").set(3)
    text = reg.to_prometheus()
    assert validate_exposition(text) == 3
    assert 'repro_test_total{shard="0"} 3' in text
    assert 'repro_test_total{shard="1"} 7' in text
    doc = reg.to_json()
    assert doc["repro_test_total"]["type"] == "counter"
    vals = {tuple(s["labels"].items()): s["value"]
            for s in doc["repro_test_total"]["samples"]}
    assert vals[(("shard", "0"),)] == 3


def test_metrics_histogram_exposition():
    reg = MetricsRegistry()
    h = reg.histogram("repro_test_ms", "latency",
                      buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    text = reg.to_prometheus()
    assert validate_exposition(text) > 0
    assert 'repro_test_ms_bucket{le="10"} 2' in text
    assert 'repro_test_ms_bucket{le="+Inf"} 4' in text
    assert "repro_test_ms_count 4" in text
    assert "repro_test_ms_sum 555.5" in text


def test_metrics_declaration_hygiene():
    reg = MetricsRegistry()
    reg.counter("repro_ok_total", "x", labelnames=("a",))
    # idempotent re-declaration returns the same family
    assert reg.counter("repro_ok_total", "x", labelnames=("a",)) \
        is reg.get("repro_ok_total")
    with pytest.raises(ValueError, match="re-declared"):
        reg.gauge("repro_ok_total", "x", labelnames=("a",))
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name", "x")
    with pytest.raises(ValueError, match="got labels"):
        reg.get("repro_ok_total").labels(b=1)
    with pytest.raises(TypeError):
        reg.histogram("repro_h", "x").inc()


def test_validate_exposition_rejects_malformed():
    with pytest.raises(ValueError, match="no TYPE"):
        validate_exposition("orphan_sample 1\n")
    with pytest.raises(ValueError, match="malformed sample"):
        validate_exposition("# TYPE x counter\nx{bad 1\n")
    with pytest.raises(ValueError, match="no samples"):
        validate_exposition("# TYPE x counter\n")


# ---- trace spans -----------------------------------------------------------

def test_tracer_spans_and_validation():
    tr = Tracer()
    with tr.span("prefill", slot=1):
        pass
    ts = tr.begin()
    tr.complete("tick", ts, cat="compile", step=0)
    ts = tr.begin()
    tr.complete("tick", ts, cat="steady", step=1)
    tr.instant("cancel", uid=3)
    doc = tr.to_json()
    assert validate_trace(doc, require_phases=True) == 4
    names = [ev["name"] for ev in doc["traceEvents"]]
    assert names == ["prefill", "tick", "tick", "cancel"]
    assert all(ev["name"].split("/")[0] in PHASES
               for ev in doc["traceEvents"])


def test_validate_trace_rejections():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({})
    bad = {"traceEvents": [{"name": "not_a_phase", "cat": "engine",
                            "ph": "X", "ts": 0, "dur": 1,
                            "pid": 0, "tid": 0}]}
    with pytest.raises(ValueError, match="taxonomy"):
        validate_trace(bad)
    steady_only = Tracer()
    ts = steady_only.begin()
    steady_only.complete("tick", ts, cat="steady")
    with pytest.raises(ValueError, match="compile"):
        validate_trace(steady_only.to_json(), require_phases=True)
    # but fine without the replay acceptance requirement
    assert validate_trace(steady_only.to_json()) == 1


def test_null_tracer_is_noop():
    before = len(NULL.events)
    with NULL.span("tick"):
        pass
    NULL.complete("tick", NULL.begin())
    NULL.instant("cancel")
    assert len(NULL.events) == before


def test_step_monitor_publishes_through_registry():
    from repro.ft.runtime import StepMonitor
    reg = MetricsRegistry()
    mon = StepMonitor(warmup=1, registry=reg)
    for _ in range(3):
        mon.start()
        mon.stop()
    text = reg.to_prometheus()
    assert validate_exposition(text) > 0
    steps = reg.get("repro_steps_total").samples[()]
    assert steps == 3
    assert reg.get("repro_step_time_ms").samples[()].count == 3
    assert reg.get("repro_step_time_ewma_ms") is not None
