"""Fused decode mega-step tests (serve/engine.py, DESIGN.md §11).

The contract under test: ``mega_step=True`` produces token streams
IDENTICAL to the host-loop decode — across allocator backends,
lowerings, and shard counts — while executing grow + forward + sample
as ONE jitted tick whose kernel-launch count is independent of
``max_batch``.  Failure recovery (defrag-retry on page exhaustion) and
the proactive ``defrag_threshold`` trigger ride the same suite.

Everything runs float32 (kv + compute): greedy argmax parity must be
bit-exact, not merely close.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.model import build_model

pytestmark = pytest.mark.serve


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_arch("qwen2-0.5b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _run(tiny_model, mega, *, n_req=4, max_new=5, seed=0, **kw):
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                        kv_dtype=jnp.float32, compute_dtype=jnp.float32,
                        mega_step=mega, **kw)
    rng = np.random.default_rng(seed)
    for _ in range(n_req):
        eng.submit(rng.integers(2, cfg.vocab_size,
                                int(rng.integers(4, 30))),
                   max_new_tokens=max_new)
    done = eng.run_until_done(300)
    assert len(done) == n_req
    return {r.uid: r.out_tokens for r in done}, eng


@pytest.fixture(scope="module")
def host_tokens(tiny_model):
    """Host-loop reference streams (jnp backend — the oracle)."""
    toks, eng = _run(tiny_model, False)
    assert eng.stats["frees"] == eng.stats["allocs"]
    return toks


@pytest.mark.parametrize("backend,lowering,shards", [
    ("jnp", "auto", 1),
    ("jnp", "auto", 4),
    ("pallas", "whole", 1),
    ("pallas", "blocked", 1),
    ("pallas", "auto", 4),
])
def test_mega_matches_host_loop(tiny_model, host_tokens, backend,
                                lowering, shards):
    """Token-for-token: the fused tick replays the host loop exactly,
    whatever transaction backend/lowering/shard count grows the KV
    heap underneath it."""
    toks, eng = _run(tiny_model, True, alloc_backend=backend,
                     alloc_lowering=lowering, num_shards=shards)
    assert toks == host_tokens
    assert eng.stats["frees"] == eng.stats["allocs"]
    assert eng.stats["alloc_failures"] == 0
    assert eng.stats["mega_step"] is True


def test_mega_handles_max_new_one(tiny_model):
    """Finish-semantics edge: ``max_new_tokens=1`` yields TWO tokens on
    the host path (prefill token + the decode append that detects the
    budget); the mega budget accounting must reproduce that, not
    truncate at one."""
    h, _ = _run(tiny_model, False, n_req=2, max_new=1)
    g, _ = _run(tiny_model, True, n_req=2, max_new=1)
    assert h == g
    assert all(len(t) == 2 for t in g.values())


def test_mega_eos_parity(tiny_model):
    """EOS early-exit fires on the same tick in both modes: pick the
    token the reference emits mid-stream as eos_id and rerun."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    h, _ = _run(tiny_model, False, n_req=2, max_new=6, seed=3)
    eos = h[1][2]  # third emitted token of request 1

    def gen(mega):
        eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                            kv_dtype=jnp.float32,
                            compute_dtype=jnp.float32, mega_step=mega)
        rng = np.random.default_rng(3)
        for _ in range(2):
            eng.submit(rng.integers(2, cfg.vocab_size,
                                    int(rng.integers(4, 30))),
                       max_new_tokens=6, eos_id=eos)
        done = eng.run_until_done(300)
        return {r.uid: r.out_tokens for r in done}

    a, b = gen(False), gen(True)
    assert a == b
    assert len(a[1]) < 6  # the eos actually cut request 1 short


def test_mega_launch_count_constant_in_batch(tiny_model):
    """The tentpole claim: launches per fused tick read off the jaxpr
    — exactly ONE pallas_call with alloc_backend="pallas" (the bulk
    grow transaction; decode attention rides the jnp paged path), zero
    with the jnp oracle, and the SAME at any max_batch."""
    from benchmarks.common import launches_per_tick
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    counts = {}
    for backend in ("jnp", "pallas"):
        per_batch = []
        for mb in (2, 8):
            eng = ServingEngine(m, params, max_batch=mb, max_seq=96,
                                kv_dtype=jnp.float32,
                                compute_dtype=jnp.float32,
                                mega_step=True, alloc_backend=backend)
            n = launches_per_tick(eng)
            assert eng.stats["launches_per_tick"] == n
            per_batch.append(n)
        assert per_batch[0] == per_batch[1], (backend, per_batch)
        counts[backend] = per_batch[0]
    assert counts == {"jnp": 0, "pallas": 1}


def test_launches_per_tick_works_without_mega(tiny_model):
    """Host-mode engines report a launch count too (obs/, DESIGN.md
    §14): the jitted decode program plus the bulk-grow transaction
    dispatched around it, read off the jaxprs like the mega count —
    so BENCH_serve host cells and mega cells are directly comparable."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32)
    n = eng.launches_per_tick()
    assert isinstance(n, int) and n >= 0
    assert eng.stats["launches_per_tick"] == n
    # the pallas allocator contributes exactly the one fused grow
    # kernel on top of the jnp count, and the count is constant in
    # max_batch, like the mega-path proof above
    engp = ServingEngine(m, params, max_batch=4, max_seq=64,
                         kv_dtype=jnp.float32, alloc_backend="pallas")
    assert engp.launches_per_tick() == n + 1


def test_mega_rejects_overlong_request(tiny_model):
    """The device token buffer is sized at construction; a submit past
    it must fail loudly at submit time, not corrupt out_buf later."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                        kv_dtype=jnp.float32, mega_step=True,
                        max_new_cap=8)
    with pytest.raises(ValueError, match="max_new_cap"):
        eng.submit(np.arange(2, 10), max_new_tokens=9)


def test_decode_syncs_token_ids_not_logits(tiny_model):
    """Legacy-path fix: the jitted decode/prefill entries argmax ON
    DEVICE — the host fetch is (B,) int32 ids, never (B, vocab)
    logits."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    eng = ServingEngine(m, params, max_batch=3, max_seq=96,
                        kv_dtype=jnp.float32)
    toks = jnp.zeros((3, 1), jnp.int32)
    ids, _ = jax.eval_shape(eng._decode, params, toks, eng.caches)
    assert ids.shape == (3,) and ids.dtype == jnp.int32


@pytest.mark.defrag
def test_mega_recovers_from_exhaustion(tiny_model):
    """The exhaustion-recovery trace of test_defrag, replayed through
    the mega-step: alloc failure surfaces in the per-tick flags, the
    host reclaims the failed slots' partial grants, runs a defrag
    wave, and the retried ticks produce the SAME streams the host
    loop does."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model

    def trace(mega):
        eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                            kv_dtype=jnp.float32,
                            compute_dtype=jnp.float32, num_pages=16,
                            mega_step=mega)
        n = 16
        big = jnp.full(n, 2048, jnp.int32)
        st, offs = eng.ouro.alloc(eng.alloc_state, big,
                                  jnp.ones(n, bool))
        granted = np.asarray(offs) >= 0
        assert granted.any()
        eng.alloc_state = eng.ouro.free(st, offs, big,
                                        jnp.asarray(granted))
        rng = np.random.default_rng(1)
        for _ in range(2):
            eng.submit(rng.integers(2, cfg.vocab_size, 40),
                       max_new_tokens=8)
        done = eng.run_until_done(100)
        assert len(done) == 2
        return sorted(tuple(r.out_tokens) for r in done), eng

    h, _ = trace(False)
    g, eng = trace(True)
    assert h == g
    assert eng.stats["defrag_waves"] > 0
    assert eng.stats["frees"] == eng.stats["allocs"]


@pytest.mark.defrag
def test_exhaustion_evicts_instead_of_raising(tiny_model):
    """When defrag cannot reclaim (a co-tenant HOLDS the heap live),
    both decode paths degrade gracefully instead of raising
    MemoryError: the youngest slot is evicted (its pages freed, its
    request requeued, ``evictions`` counted) and the engine stays
    serviceable — once the co-tenant releases its pages, the evicted
    request replays and completes with the identical greedy stream."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model

    def run(mega):
        eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                            kv_dtype=jnp.float32,
                            compute_dtype=jnp.float32, num_pages=16,
                            mega_step=mega)
        # co-tenant takes every 256 B page, hands exactly 2 back:
        # enough to admit a 30-token prompt, not enough to grow.
        sizes = jnp.full(64, 256, jnp.int32)
        eng.alloc_state, offs = eng.ouro.alloc(
            eng.alloc_state, sizes, jnp.ones(64, bool))
        offs = np.asarray(offs)
        held = offs[offs >= 0]
        back = np.full(64, -1, np.int32)
        back[:2] = held[:2]
        eng.alloc_state = eng.ouro.free(
            eng.alloc_state, jnp.asarray(back), sizes,
            jnp.asarray(back >= 0))
        eng.submit(np.random.default_rng(1).integers(
            2, cfg.vocab_size, 30), max_new_tokens=30)
        # serve into the wall: no exception, eviction(s) instead, and
        # the request is parked (requeued or re-admitted), not lost
        for _ in range(30):
            assert eng.step() == []
        assert eng.stats["evictions"] > 0
        assert (len(eng.waiting)
                + sum(r is not None for r in eng.slot_req)) == 1
        # co-tenant releases the heap → the evicted request replays
        rest = np.full(64, -1, np.int32)
        rest[:len(held) - 2] = held[2:]
        eng.alloc_state = eng.ouro.free(
            eng.alloc_state, jnp.asarray(rest), sizes,
            jnp.asarray(rest >= 0))
        done = eng.run_until_done(200)
        assert len(done) == 1 and done[0].out_tokens
        return done[0].out_tokens

    assert run(False) == run(True)


@pytest.mark.defrag
def test_auto_defrag_threshold_trigger(tiny_model):
    """S1: past ``defrag_threshold`` the engine fires a proactive
    defrag wave mid-serve (counted in ``auto_defrag_waves``); with the
    default ``None`` it never does."""
    from repro.serve.engine import ServingEngine
    cfg, m, params = tiny_model
    for thresh, fires in ((0.05, True), (None, False)):
        eng = ServingEngine(m, params, max_batch=2, max_seq=64,
                            kv_dtype=jnp.float32, num_pages=32,
                            defrag_threshold=thresh)
        # checkerboard co-tenant: free every other page → high
        # frag_ratio that persists while the engine serves
        sizes = jnp.full(32, 256, jnp.int32)
        eng.alloc_state, offs = eng.ouro.alloc(
            eng.alloc_state, sizes, jnp.ones(32, bool))
        offs = np.asarray(offs)
        odd = (np.arange(32) % 2 == 0) & (offs >= 0)
        eng.alloc_state = eng.ouro.free(eng.alloc_state,
                                        jnp.asarray(offs), sizes,
                                        jnp.asarray(odd))
        eng.submit(np.arange(2, 20) % cfg.vocab_size, max_new_tokens=4)
        eng.run_until_done(50)
        assert (eng.stats["auto_defrag_waves"] >= 1) == fires


def test_engine_validates_defrag_knobs(tiny_model):
    from repro.serve.engine import ServingEngine
    with pytest.raises(ValueError, match="defrag_threshold"):
        ServingEngine(None, None, defrag_threshold=1.5)
    with pytest.raises(ValueError, match="defrag_check_interval"):
        ServingEngine(None, None, defrag_check_interval=0)
    with pytest.raises(ValueError, match="max_new_cap"):
        ServingEngine(None, None, max_new_cap=0)
