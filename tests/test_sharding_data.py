"""Sharding-rule mapping + data-pipeline determinism tests."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import SHAPES, ShapeConfig, cells_for
from repro.data.pipeline import DataConfig, batch_at, input_specs
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.parallel.sharding import ShardingRules


class FakeMesh:
    """Shape-only stand-in so rule mapping is testable without devices."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _rules(multi_pod=False):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi_pod
                    else {"data": 16, "model": 16})
    return ShardingRules(mesh=mesh, rules=ShardingRules.for_mesh.__func__(
        ShardingRules, mesh).rules)


def test_param_spec_mapping_single_pod():
    r = _rules()
    # embedding: vocab→model, embed→data
    assert r.spec_for(("vocab", "embed"), (151936, 896)) == P("model", "data")
    # merged attention: embed→data, heads→model
    assert r.spec_for(("embed", "heads"), (5120, 5120)) == P("data", "model")
    # non-divisible dim stays unsharded (jit in_shardings are strict)
    assert r.spec_for(("embed", "heads"), (5120, 40)) == P("data")
    # mlp weight
    assert r.spec_for(("embed", "mlp"), (4096, 14336)) == P("data", "model")


def test_param_spec_mapping_multi_pod():
    r = _rules(multi_pod=True)
    got = r.spec_for(("embed", "mlp"), (4096, 14336))
    assert got == P(("pod", "data"), "model")
    # dim not divisible by pod*data=32 → drops fsdp mapping
    assert r.spec_for(("embed",), (5,)) == P()


def test_activation_specs():
    r = _rules(multi_pod=True)
    assert r.spec_for(("batch", "seq", "act_embed"),
                      (256, 4096, 4096)) == P(("pod", "data"), "model")
    # decode: seq=1 → no SP
    assert r.spec_for(("batch", "seq", "act_embed"),
                      (128, 1, 4096)) == P(("pod", "data"))


def test_no_axis_used_twice():
    r = _rules()
    spec = r.spec_for(("vocab", "heads"), (256, 256))  # both want 'model'
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


def test_cells_for_skips():
    """long_500k only for sub-quadratic archs (DESIGN §Arch-applicability)."""
    names = {a: [s.name for s in cells_for(get_arch(a))]
             for a in ("qwen1.5-32b", "mamba2-780m", "mixtral-8x7b",
                       "recurrentgemma-9b", "command-r-35b")}
    assert "long_500k" not in names["qwen1.5-32b"]
    assert "long_500k" not in names["command-r-35b"]
    assert "long_500k" in names["mamba2-780m"]
    assert "long_500k" in names["mixtral-8x7b"]
    assert "long_500k" in names["recurrentgemma-9b"]
    total = sum(len(cells_for(get_arch(a))) for a in
                [a for a in __import__("repro.configs",
                                       fromlist=["ALL_ARCHS"]).ALL_ARCHS])
    assert total == 33  # 10×3 + 3 long_500k


# ---- data pipeline -----------------------------------------------------------

def test_batch_determinism():
    cfg = get_arch("qwen2-0.5b").smoke()
    shape = ShapeConfig("t", 64, 4, "train")
    d = DataConfig(seed=5)
    b1 = batch_at(cfg, shape, d, step=3)
    b2 = batch_at(cfg, shape, d, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, shape, d, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_shard_slices_disjoint():
    cfg = get_arch("qwen2-0.5b").smoke()
    shape = ShapeConfig("t", 32, 8, "train")
    full = batch_at(cfg, shape, DataConfig(seed=1), 0)
    s0 = batch_at(cfg, shape, DataConfig(seed=1, shard_index=0,
                                         num_shards=2), 0)
    s1 = batch_at(cfg, shape, DataConfig(seed=1, shard_index=1,
                                         num_shards=2), 0)
    np.testing.assert_array_equal(full["tokens"][:4], s0["tokens"])
    np.testing.assert_array_equal(full["tokens"][4:], s1["tokens"])


def test_input_specs_match_batches():
    for arch in ("qwen2-vl-2b", "seamless-m4t-large-v2", "qwen2-0.5b"):
        cfg = get_arch(arch)
        spec = input_specs(cfg, SHAPES["train_4k"])
        smoke_shape = ShapeConfig("t", 16, 2, "train")
        batch = batch_at(cfg.smoke(), smoke_shape, DataConfig(), 0)
        # spec keys ⊇ batch keys minus smoke-dependent dims
        for k in batch:
            assert k in spec, (arch, k)
