"""Golden snapshot of the arena word layout AND the per-region blocked
-lowering treatment.

DESIGN.md §7 documents the offset map and §8 the region-blocking
scheme; both are rendered from the live ``ArenaLayout`` (test_heap.py
pins §7 prose to ``describe()``).  This test goes one step further and
pins the full rendering — offsets, shapes, blocking policy, and VMEM
block shape per region, for all six variants — to a checked-in golden
file, so ANY layout drift (a reordered region, a changed block shape,
a region silently promoted to a whole-VMEM load) fails loudly instead
of silently breaking cross-lowering parity or corrupting live heaps on
a version upgrade.

To regenerate after an *intentional* layout change:

    PYTHONPATH=src python -c "
    from repro.core import HeapConfig, arena
    cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                     min_page_bytes=16)
    print('\\n'.join(arena.layout(cfg, k, f).describe(blocks=True)
                     for k in arena.KINDS
                     for f in arena.QUEUE_FAMILIES))
    " > tests/golden/arena_layout.txt

and justify the diff in the PR.
"""
import pathlib

import pytest

from repro.core import HeapConfig
from repro.core import arena

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
GOLDEN = pathlib.Path(__file__).parent / "golden" / "arena_layout.txt"


def _render() -> str:
    return "\n".join(arena.layout(CFG, kind, family).describe(blocks=True)
                     for kind in arena.KINDS
                     for family in arena.QUEUE_FAMILIES) + "\n"


def test_layout_and_block_shapes_match_golden():
    want = GOLDEN.read_text()
    got = _render()
    assert got == want, (
        "arena layout or region block shapes drifted from the golden "
        "snapshot (tests/golden/arena_layout.txt).  If the change is "
        "intentional, regenerate the golden file (see module "
        "docstring) and call the drift out in the PR — live arenas "
        "serialized under the old layout will NOT survive it.")


@pytest.mark.parametrize("kind", arena.KINDS)
@pytest.mark.parametrize("family", arena.QUEUE_FAMILIES)
def test_block_shapes_consistent_with_policy(kind, family):
    """Structural invariants the blocked lowering relies on, config-
    independent: row-blocked regions are 2-D with one-row blocks, hbm
    regions never present a VMEM block, and untouched regions are
    exactly the ones the transactions never write."""
    lay = arena.layout(CFG, kind, family)
    for r in lay.regions:
        if r.blocking == "row":
            assert len(r.shape) == 2 and r.block_shape == (1, r.shape[1])
        elif r.blocking == "resident":
            assert r.block_shape == r.shape
        else:
            assert r.block_shape is None
    # the heap is written only by segment traffic; the pool only ever
    # moves for virtualized queues or chunk claims
    assert (lay.region("heap").blocking == "untouched") == \
        (family == "ring")
    assert (lay.region("pool_store").blocking == "untouched") == \
        (family == "ring" and kind == "page")


def test_split_join_roundtrip():
    """split/join (the blocked wrapper's mem plumbing) is lossless."""
    import jax.numpy as jnp
    lay = arena.layout(CFG, "chunk", "vl")
    mem = jnp.arange(lay.mem_words, dtype=jnp.int32)
    parts = arena.split(lay, mem)
    assert set(parts) == {r.name for r in lay.regions}
    assert (arena.join(lay, parts) == mem).all()


# ---- sharded layout golden (DESIGN.md §9) ---------------------------------

from repro.core import shards

SHARD_GOLDEN = pathlib.Path(__file__).parent / "golden" / "shard_layout.txt"
SHARDS = 4


def _render_sharded() -> str:
    return "\n".join(
        shards.layout(CFG, SHARDS, kind, family).describe(blocks=True)
        for kind in arena.KINDS for family in arena.QUEUE_FAMILIES) + "\n"


def test_shard_layout_matches_golden():
    """The sharded layout rendering — per-shard word table, global
    offset rule, routing line — is pinned like the single-arena one.
    Regenerate intentionally with:

        PYTHONPATH=src python -c "
        from repro.core import HeapConfig, shards, arena
        cfg = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                         min_page_bytes=16)
        print('\\n'.join(shards.layout(cfg, 4, k, f).describe(blocks=True)
                         for k in arena.KINDS
                         for f in arena.QUEUE_FAMILIES))
        " > tests/golden/shard_layout.txt
    """
    want = SHARD_GOLDEN.read_text()
    got = _render_sharded()
    assert got == want, (
        "sharded arena layout drifted from the golden snapshot "
        "(tests/golden/shard_layout.txt).  If intentional, regenerate "
        "(see docstring) and call the diff out in the PR — sharded "
        "arenas serialized under the old layout will NOT survive it.")


def test_shard_layout_embeds_per_shard_arena_layout():
    """A shard's layout IS the single-arena layout of the per-shard
    config — the property that lets arena.split/join and both kernel
    lowerings run per shard unchanged."""
    for kind in arena.KINDS:
        for family in arena.QUEUE_FAMILIES:
            slay = shards.layout(CFG, SHARDS, kind, family)
            scfg = shards.shard_config(CFG, SHARDS)
            assert slay.shard is arena.layout(scfg, kind, family)
            assert slay.mem_words == slay.shard.mem_words
            assert slay.shard_words * SHARDS == CFG.total_words


def test_shard_split_join_roundtrip():
    """shards.split_regions/join_regions (the sharded blocked
    wrapper's mem plumbing) is lossless over the stacked image."""
    import jax.numpy as jnp
    slay = shards.layout(CFG, SHARDS, "chunk", "vl")
    mem = jnp.arange(SHARDS * slay.mem_words,
                     dtype=jnp.int32).reshape(SHARDS, slay.mem_words)
    parts = shards.split_regions(slay, mem)
    assert set(parts) == {r.name for r in slay.shard.regions}
    assert (shards.join_regions(slay, parts) == mem).all()
