"""Edge cases of the static heap math: `_clz32` boundaries and the
vectorized size→class mapping (`heap.size_to_class_device`).

The device mapping is shared verbatim by both transaction backends
(it runs *inside* the fused arena kernel), so a wrong class here would
corrupt every variant identically — parity alone can't catch it, only
direct boundary tests can."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.heap import HeapConfig, _clz32, size_to_class_device

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)  # classes 16 B .. 2 KiB → C = 8


def _classes(sizes):
    return list(np.asarray(
        size_to_class_device(CFG, jnp.asarray(sizes, jnp.int32))))


# ---- _clz32 ---------------------------------------------------------------

def test_clz32_boundaries():
    x = jnp.asarray([0, 1, 2, 3, 2**30, 2**31 - 1], jnp.int32)
    got = list(np.asarray(_clz32(x)))
    assert got == [32, 31, 30, 30, 1, 1]


def test_clz32_matches_numpy_reference():
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        rng.integers(0, 2**31 - 1, 64),
        [0, 1, 2**31 - 1] + [2**k for k in range(31)]]).astype(np.int64)
    got = np.asarray(_clz32(jnp.asarray(vals, jnp.int32)))
    want = [32 if v == 0 else 32 - int(v).bit_length() for v in vals]
    np.testing.assert_array_equal(got, want)


# ---- size_to_class_device -------------------------------------------------

def test_tiny_sizes_clamp_to_smallest_class():
    # 0 and 1 clamp to min_page (16 B) → class 0, like the host math.
    assert _classes([0, 1, 15, 16]) == [0, 0, 0, 0]


def test_exact_class_boundaries():
    # 2^k is the last size of class k-log2(min); 2^k + 1 spills up.
    sizes, want = [], []
    for c in range(CFG.num_classes):
        p = CFG.page_bytes(c)
        sizes += [p - 1, p, p + 1]
        want += [c, c, min(c + 1, CFG.num_classes)]
    # p-1 of class 0 is 15 → clamps to class 0 (not class -1)
    want[0] = 0
    assert _classes(sizes) == want
    # host math agrees on every in-range boundary
    for s, w in zip(sizes, want):
        if w < CFG.num_classes:
            assert CFG.size_to_class(s) == w


def test_oversize_maps_to_invalid_class():
    C = CFG.num_classes
    got = _classes([CFG.chunk_bytes + 1, CFG.chunk_bytes * 2, 2**30,
                    2**31 - 1])
    assert got == [C, C, C, C]


def test_negative_sizes_are_invalid_not_small():
    """A >2 GiB request wraps negative after the int32 cast; it must
    fail like an over-large request, never be granted a 16 B page."""
    C = CFG.num_classes
    assert _classes([-1, -(2**31), -4096]) == [C, C, C]


def test_invalid_class_lanes_fail_in_alloc():
    from repro.core import Ouroboros
    ouro = Ouroboros(CFG, "page")
    st = ouro.init()
    sizes = jnp.asarray([64, -1, CFG.chunk_bytes * 2, 64], jnp.int32)
    st, offs = ouro.alloc(st, sizes, jnp.ones(4, bool))
    offs = np.asarray(offs)
    assert offs[0] >= 0 and offs[3] >= 0
    assert offs[1] == -1 and offs[2] == -1


# ---- arena layout <-> DESIGN.md §7 ---------------------------------------

def test_design_doc_layout_tables_match_live_layout():
    """DESIGN.md §7's example offset tables are rendered from
    ``ArenaLayout.describe()``; re-render and require the mem lines to
    appear verbatim so doc and layout cannot drift apart silently."""
    import pathlib

    from repro.core import arena
    doc = (pathlib.Path(__file__).resolve().parent.parent
           / "DESIGN.md").read_text()
    for kind, family in (("page", "ring"), ("chunk", "vl")):
        desc = arena.layout(CFG, kind, family).describe()
        mem_lines = [ln for ln in desc.splitlines() if "mem[" in ln
                     or ln.startswith("arena(")]
        for ln in mem_lines:
            assert ln in doc, (
                f"DESIGN.md §7 drifted from the live layout: {ln!r}")


def test_arena_layout_regions_are_contiguous_and_disjoint():
    from repro.core import arena
    for kind in ("page", "chunk"):
        for family in ("ring", "va", "vl"):
            lay = arena.layout(CFG, kind, family)
            pos = 0
            for r in lay.regions:
                assert r.offset == pos, f"{kind}/{family}: gap at {r.name}"
                pos = r.end
            assert pos == lay.mem_words
            assert lay.core_ctl_words == 4 * CFG.num_classes + 2
            assert lay.ctl_words == (lay.core_ctl_words
                                     + lay.tele_words)
            assert lay.tele_words == (4 * CFG.num_classes + 3
                                      + arena.TELE_WALK_BINS)
