"""Stateful allocator model: random alloc/free traces replayed through
every (backend, lowering) implementation against a pure-Python
reference model.

The model tracks live grants as an interval map and asserts, after
every transaction and for each of the six variants:

- **uniqueness** — no live offset is ever handed out twice;
- **containment** — every grant lies inside its size class's region:
  the offset is within the heap, aligned to the class page size, and
  the granted page [offset, offset + page_words) never crosses a chunk
  boundary (pages are carved from chunks — paper §4);
- **non-overlap** — granted pages of live allocations are disjoint;
- **reuse** — free-then-realloc hands pages back out: after freeing k
  class-c pages, a fresh batch of k same-class requests succeeds.

All implementations — the jnp oracle, the whole-arena Pallas kernel,
and the region-blocked compiled lowering — replay the same trace in
lockstep and must grant identical offsets (exact-equality cross-check
on top of the model invariants).

``hypothesis`` is optional, following test_allocator_hypothesis.py:
with it installed the trace generator runs under shrinking strategies;
without it, seeded ``np.random`` traces replay the same checker so the
invariants stay guarded either way.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

try:  # optional dependency — see fallback below
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
SIZES = [16, 24, 100, 256, 1000, 2048]
N = 16  # lane width shared with test_alloc_txn_parity: one jit cache

# every implementation triple replayed in lockstep
IMPLS = (("jnp", "auto"), ("pallas", "whole"), ("pallas", "blocked"))


class RefModel:
    """Pure-Python reference allocator model (host truth).

    ``num_shards > 1`` additionally asserts SHARD containment: every
    grant lies entirely inside one shard's heap slice (offsets are
    global — shard · shard_words + local — so a page straddling a
    shard boundary would corrupt a neighbor's words), and alignment /
    chunk containment hold for the shard-LOCAL offset.  Non-overlap is
    asserted on global offsets, so it also guards cross-shard overlap:
    two shards handing out the same global word would trip it."""

    def __init__(self, cfg: HeapConfig, num_shards: int = 1):
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard_words = cfg.total_words // num_shards
        self.live = {}  # offset -> (size_bytes, class, page_words)

    def on_alloc(self, offs, sizes):
        cfg = self.cfg
        for o, s in zip(offs, sizes):
            if o < 0:
                continue
            o, s = int(o), int(s)
            c = cfg.size_to_class(s)
            pw = cfg.page_words(c)
            # containment: in-heap, shard-contained, class-aligned
            # (local offset), chunk-contained
            assert 0 <= o < cfg.total_words, (o, s)
            assert o // self.shard_words == \
                (o + pw - 1) // self.shard_words, \
                f"page at {o} crosses a shard boundary"
            local = o % self.shard_words
            assert local % pw == 0, \
                f"offset {o} (local {local}) not aligned to class {c}"
            assert o // cfg.words_per_chunk == \
                (o + pw - 1) // cfg.words_per_chunk, \
                f"page at {o} crosses a chunk boundary"
            # uniqueness: never granted twice while live
            assert o not in self.live, f"offset {o} double-granted"
            # non-overlap against every live page (global offsets, so
            # cross-shard overlap is caught too)
            for lo, (_, _, lpw) in self.live.items():
                assert o + pw <= lo or lo + lpw <= o, \
                    f"grant [{o},{o + pw}) overlaps live [{lo},{lo + lpw})"
            self.live[o] = (s, c, pw)

    def on_free(self, offs):
        for o in offs:
            self.live.pop(int(o), None)


def _mk(variant, num_shards: int = 1):
    return [Ouroboros(CFG, variant, backend, lowering,
                      num_shards=num_shards)
            for backend, lowering in IMPLS]


def _lockstep_alloc(impls, states, sizes, mask):
    outs = [o.alloc(s, sizes, mask) for o, s in zip(impls, states)]
    states = [s for s, _ in outs]
    offs = [np.asarray(x) for _, x in outs]
    for got, (backend, lowering) in zip(offs[1:], IMPLS[1:]):
        np.testing.assert_array_equal(
            offs[0], got,
            err_msg=f"{backend}/{lowering} diverged from the oracle")
    return states, offs[0]


def check_model_trace(variant, ops, seed, num_shards: int = 1):
    """Replay ``ops`` through all implementations, assert the model
    invariants and cross-implementation grant equality throughout."""
    rng = np.random.default_rng(seed)
    impls = _mk(variant, num_shards)
    states = [o.init() for o in impls]
    model = RefModel(CFG, num_shards)

    for kind, sizes in ops:
        k = min(len(sizes), N)
        if kind == "alloc":
            sz = np.zeros(N, np.int32)
            sz[:k] = sizes[:k]
            mask = jnp.asarray(np.arange(N) < k)
            states, offs = _lockstep_alloc(
                impls, states, jnp.asarray(sz, jnp.int32), mask)
            model.on_alloc(offs[:k], sz[:k])
        else:
            if not model.live:
                continue
            keys = list(model.live)
            pick = rng.choice(len(keys), min(len(keys), k),
                              replace=False)
            drop = [keys[i] for i in pick]
            fo = np.full(N, -1, np.int32)
            fs = np.zeros(N, np.int32)
            fo[:len(drop)] = drop
            fs[:len(drop)] = [model.live[o][0] for o in drop]
            fm = jnp.asarray(fo >= 0)
            states = [o.free(s, jnp.asarray(fo), jnp.asarray(fs), fm)
                      for o, s in zip(impls, states)]
            model.on_free(drop)

    # reuse: free every live grant of the most common class, then
    # re-alloc that many pages of the class — all must succeed.
    if model.live:
        classes = [c for (_, c, _) in model.live.values()]
        c = max(set(classes), key=classes.count)
        drop = [o for o, (_, cc, _) in model.live.items() if cc == c]
        k = min(len(drop), N)
        fo = np.full(N, -1, np.int32)
        fs = np.zeros(N, np.int32)
        fo[:k] = drop[:k]
        fs[:k] = [model.live[o][0] for o in drop[:k]]
        fm = jnp.asarray(fo >= 0)
        states = [o.free(s, jnp.asarray(fo), jnp.asarray(fs), fm)
                  for o, s in zip(impls, states)]
        model.on_free(drop[:k])
        sz = np.zeros(N, np.int32)
        sz[:k] = CFG.page_bytes(c)
        mask = jnp.asarray(np.arange(N) < k)
        states, offs = _lockstep_alloc(impls, states,
                                       jnp.asarray(sz, jnp.int32), mask)
        assert (offs[:k] >= 0).all(), \
            f"free-then-realloc failed to reuse class-{c} pages"
        model.on_alloc(offs[:k], sz[:k])


if HAVE_HYPOTHESIS:
    op = st.tuples(
        st.sampled_from(["alloc", "free"]),
        st.lists(st.sampled_from(SIZES), min_size=1, max_size=N),
    )

    @pytest.mark.compiled_lowering
    @settings(max_examples=10, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(variant=st.sampled_from(VARIANTS),
           ops=st.lists(op, min_size=1, max_size=6),
           seed=st.integers(0, 2**16))
    def test_alloc_model(variant, ops, seed):
        check_model_trace(variant, ops, seed)


def _random_ops(rng):
    """Seeded stand-in for the hypothesis strategy above (same shape
    as test_allocator_hypothesis._random_ops)."""
    ops = []
    for _ in range(int(rng.integers(2, 7))):
        kind = "alloc" if rng.random() < 0.6 else "free"
        ops.append((kind, [int(s) for s in rng.choice(SIZES, N)]))
    return ops


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("seed", [0, 1])
def test_alloc_model_fallback(variant, seed):
    """Pure-pytest randomized form of the stateful model property:
    runs with or without hypothesis installed."""
    rng = np.random.default_rng(seed)
    check_model_trace(variant, _random_ops(rng), seed)


# ---- grow_lanes lane routing (decode mega-step entry) ---------------------
#
# transactions.grow_lanes is the searchsorted-over-cumsum expansion the
# fused decode tick uses to turn a per-slot page-need vector into
# allocation lanes; until now it was only covered through engine-level
# traces.  The host-truth reference is the obvious repeat-and-slice.

def _ref_grow_lanes(need, lanes):
    need = np.asarray(need, np.int64)
    slot = np.repeat(np.arange(need.shape[0]), need)[:lanes]
    rank = np.concatenate(
        [np.arange(n, dtype=np.int64) for n in need] or
        [np.zeros(0, np.int64)])[:lanes]
    mask = np.arange(lanes) < slot.shape[0]
    return slot, rank, mask


def check_grow_lanes(need, lanes):
    from repro.core.transactions import grow_lanes

    slot, rank, mask = grow_lanes(jnp.asarray(need, jnp.int32), lanes)
    slot, rank, mask = map(np.asarray, (slot, rank, mask))
    rslot, rrank, rmask = _ref_grow_lanes(need, lanes)
    assert (mask == rmask).all(), (need, lanes, mask, rmask)
    k = int(rmask.sum())
    assert (slot[:k] == rslot).all(), (need, lanes, slot, rslot)
    assert (rank[:k] == rrank).all(), (need, lanes, rank, rrank)
    assert (rank[k:] == 0).all(), "masked lanes must pin rank to 0"


@pytest.mark.parametrize("need,lanes", [
    ([0, 0, 0, 0], 8),          # all lanes zero-need → all masked
    ([0], 1),
    ([7], 4),                   # one slot wants the whole budget + more
    ([4], 4),                   # ...exactly the budget
    ([0, 9, 0], 6),             # truncation inside a middle slot
    ([2, 0, 1], 3),             # zero-need slot between live ones
    ([1, 1, 1, 1], 2),          # truncation across slots
    ([3, 5], 8),                # exact fill, no masked tail
    ([0, 0, 2], 8),             # demand only in the last slot
])
def test_grow_lanes_edges(need, lanes):
    check_grow_lanes(need, lanes)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(need=st.lists(st.integers(0, 9), min_size=1, max_size=8),
           lanes=st.integers(1, 24))
    def test_grow_lanes_property(need, lanes):
        check_grow_lanes(need, lanes)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_grow_lanes_property_fallback(seed):
    """Seeded stand-in for the hypothesis property above."""
    rng = np.random.default_rng(seed)
    for _ in range(50):
        B = int(rng.integers(1, 9))
        need = [int(n) for n in rng.integers(0, 10, B)]
        check_grow_lanes(need, int(rng.integers(1, 25)))


@pytest.mark.compiled_lowering
@pytest.mark.parametrize("variant", ("page", "chunk", "va_page",
                                     "vl_chunk"))
def test_alloc_model_sharded(variant):
    """num_shards=4: the stateful invariants extended with shard
    containment (no grant straddles a shard boundary; local offsets
    stay class-aligned) and cross-shard non-overlap (global offsets are
    compared across every live grant, whichever shard granted them),
    with all three implementations in lockstep."""
    seed = 3
    rng = np.random.default_rng(seed)
    check_model_trace(variant, _random_ops(rng), seed, num_shards=4)
