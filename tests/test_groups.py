"""Masked group operations — the paper-§2 transplant layer."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import groups


@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=100))
def test_ballot_packs_bits(bits):
    out = np.asarray(groups.masked_ballot(jnp.asarray(bits)))
    for i, b in enumerate(bits):
        assert bool((out[i // 32] >> (i % 32)) & 1) == b


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.booleans()),
                min_size=1, max_size=64))
def test_masked_rank_is_dense_per_class(items):
    cls = jnp.asarray([c for c, _ in items], jnp.int32)
    mask = jnp.asarray([m for _, m in items])
    rank, counts = groups.masked_rank(cls, mask, 5)
    rank, counts = np.asarray(rank), np.asarray(counts)
    seen = {c: 0 for c in range(5)}
    for i, (c, m) in enumerate(items):
        if m:
            assert rank[i] == seen[c]  # dense, order-preserving
            seen[c] += 1
    for c in range(5):
        assert counts[c] == seen[c]


def test_masked_prefix_sum():
    x = jnp.asarray([1, 2, 3, 4])
    m = jnp.asarray([True, False, True, True])
    out = np.asarray(groups.masked_prefix_sum(x, m))
    assert list(out) == [0, 1, 1, 4]
