"""Masked group operations — the paper-§2 transplant layer.

``hypothesis`` is optional: its property tests run when installed; a
seeded pure-pytest fallback exercises the same checkers otherwise.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import groups

try:  # optional dependency — seeded fallback below covers absence
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_ballot_packs_bits(bits):
    out = np.asarray(groups.masked_ballot(jnp.asarray(bits)))
    for i, b in enumerate(bits):
        assert bool((out[i // 32] >> (i % 32)) & 1) == b


def check_masked_rank_is_dense_per_class(items):
    cls = jnp.asarray([c for c, _ in items], jnp.int32)
    mask = jnp.asarray([m for _, m in items])
    rank, counts = groups.masked_rank(cls, mask, 5)
    rank, counts = np.asarray(rank), np.asarray(counts)
    seen = {c: 0 for c in range(5)}
    for i, (c, m) in enumerate(items):
        if m:
            assert rank[i] == seen[c]  # dense, order-preserving
            seen[c] += 1
    for c in range(5):
        assert counts[c] == seen[c]


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.booleans(), min_size=1, max_size=100))
    def test_ballot_packs_bits(bits):
        check_ballot_packs_bits(bits)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 4), st.booleans()),
                    min_size=1, max_size=64))
    def test_masked_rank_is_dense_per_class(items):
        check_masked_rank_is_dense_per_class(items)


@pytest.mark.parametrize("seed", range(8))
def test_ballot_packs_bits_fallback(seed):
    rng = np.random.default_rng(seed)
    bits = [bool(b) for b in rng.random(int(rng.integers(1, 101))) < 0.5]
    check_ballot_packs_bits(bits)


@pytest.mark.parametrize("seed", range(8))
def test_masked_rank_is_dense_per_class_fallback(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 65))
    items = [(int(rng.integers(0, 5)), bool(rng.random() < 0.5))
             for _ in range(n)]
    check_masked_rank_is_dense_per_class(items)


def test_masked_prefix_sum():
    x = jnp.asarray([1, 2, 3, 4])
    m = jnp.asarray([True, False, True, True])
    out = np.asarray(groups.masked_prefix_sum(x, m))
    assert list(out) == [0, 1, 1, 4]
