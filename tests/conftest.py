import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
