import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (long system/train integration)")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "compiled_lowering: exercises the region-blocked compiled "
        "lowering of the fused arena kernels (CI runs these under "
        "REPRO_ALLOC_LOWERING=blocked as a dedicated job)")
    config.addinivalue_line(
        "markers",
        "defrag: exercises the live defragmentation subsystem "
        "(core/defrag.py, kernels/defrag_txn.py, DESIGN.md §10; wired "
        "into the forced-blocked and nightly CI jobs)")
    config.addinivalue_line(
        "markers",
        "serve: exercises the serving engine's fused decode mega-step "
        "(serve/engine.py, DESIGN.md §11; the forced-blocked CI job "
        "runs the mega-vs-host parity suite under this marker)")
    config.addinivalue_line(
        "markers",
        "ft: exercises crash-safe serving — engine snapshot/restore, "
        "layout-fingerprint validation, and exhaustion eviction "
        "(DESIGN.md §12; the forced-blocked CI job runs this marker, "
        "and the nightly job adds a kill-and-resume smoke on "
        "launch/serve.py)")
    config.addinivalue_line(
        "markers",
        "replay: exercises the traffic-replay harness — seeded trace "
        "generation, client abandonment/cancellation, mega-vs-host "
        "parity, and the all-archs serving smoke (serve/replay.py, "
        "DESIGN.md §13; the forced-blocked CI job runs this marker, "
        "and the nightly job adds the two-scenario fig9 benchmark "
        "smoke)")
    config.addinivalue_line(
        "markers",
        "obs: exercises the observability layer — in-kernel allocator "
        "telemetry word parity across lowerings, the metrics registry "
        "and Prometheus exposition, and the engine trace spans "
        "(obs/, DESIGN.md §14; the forced-blocked CI job runs this "
        "marker, and the nightly job validates the replay-emitted "
        "trace + metrics artifacts with scripts/obs_dump.py)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
