"""Property-based allocator tests: arbitrary interleavings of
alloc/free batches preserve the heap invariants on every variant.

A python-dict reference allocator tracks live intervals; after every
transaction we assert: uniqueness, in-bounds, non-overlap, and
conservation (a granted page is never granted again until freed).

``hypothesis`` is an optional dependency: when present, the properties
run under its shrinking strategies; without it, a pure-pytest fallback
replays the same checker over seeded ``np.random`` traces so the
invariants stay guarded either way (and collection never errors).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import HeapConfig, Ouroboros, VARIANTS

try:  # optional dependency — see fallback below
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = HeapConfig(total_bytes=1 << 16, chunk_bytes=1 << 11,
                 min_page_bytes=16)
SIZES = [16, 24, 100, 256, 1000, 2048]


def check_interleaved_trace(variant, ops, seed):
    """The property: replay ``ops`` (list of ("alloc"|"free", sizes))
    and assert the heap invariants after every transaction."""
    rng = np.random.default_rng(seed)
    ouro = Ouroboros(CFG, variant)
    state = ouro.init()
    live = {}  # offset -> size

    for kind, sizes in ops:
        n = len(sizes)
        if kind == "alloc":
            sz = jnp.asarray(sizes, jnp.int32)
            state, offs = ouro.alloc(state, sz, jnp.ones(n, bool))
            offs = np.asarray(offs)
            for o, s in zip(offs, sizes):
                if o < 0:
                    continue
                o = int(o)
                # in-bounds
                assert 0 <= o < CFG.total_words
                # never double-granted
                assert o not in live
                live[o] = s
            # non-overlap over all live intervals
            ivs = sorted((o, o + max(s // 4, 1)) for o, s in live.items())
            for (a, b), (c, _) in zip(ivs, ivs[1:]):
                assert c >= b
        else:
            if not live:
                continue
            keys = list(live)
            pick = rng.choice(len(keys), min(len(keys), n), replace=False)
            drop = [keys[i] for i in pick]
            m = len(drop)
            fo = jnp.asarray(drop + [0] * (n - m), jnp.int32)
            fs = jnp.asarray([live[k] for k in drop] + [0] * (n - m),
                             jnp.int32)
            fm = jnp.asarray([True] * m + [False] * (n - m))
            state = ouro.free(state, fo, fs, fm)
            for k in drop:
                del live[k]


if HAVE_HYPOTHESIS:
    op = st.tuples(
        st.sampled_from(["alloc", "free"]),
        st.lists(st.sampled_from(SIZES), min_size=1, max_size=24),
    )

    @settings(max_examples=15, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(variant=st.sampled_from(VARIANTS),
           ops=st.lists(op, min_size=1, max_size=8),
           seed=st.integers(0, 2**16))
    def test_interleaved_transactions(variant, ops, seed):
        check_interleaved_trace(variant, ops, seed)


def _random_ops(rng):
    """Seeded stand-in for the hypothesis strategy above.  Lane width
    is fixed at 16 — the same width (and heap config) as
    test_alloc_txn_parity, so each variant's transactions compile once
    per session across both suites."""
    ops = []
    for _ in range(int(rng.integers(2, 9))):
        kind = "alloc" if rng.random() < 0.6 else "free"
        ops.append((kind, [int(s) for s in rng.choice(SIZES, 16)]))
    return ops


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("seed", [0, 1])
def test_interleaved_transactions_fallback(variant, seed):
    """Pure-pytest randomized form of the property: runs with or
    without hypothesis installed."""
    rng = np.random.default_rng(seed)
    check_interleaved_trace(variant, _random_ops(rng), seed)
