"""Inspect observability artifacts (obs/, DESIGN.md §14): pretty-print
a Prometheus metrics exposition or a Chrome trace JSON, decode the
telemetry words out of a serving snapshot, or drive a quick live
replay and dump everything from it.

    # validate + summarize artifacts launch/serve.py wrote
    PYTHONPATH=src python scripts/obs_dump.py --metrics metrics.prom
    PYTHONPATH=src python scripts/obs_dump.py --trace trace.json

    # per-class / per-shard occupancy heatmap from a snapshot dir
    # (reads the ctl words + fingerprint sidecar directly — no model,
    # no engine, works on snapshots from any geometry)
    PYTHONPATH=src python scripts/obs_dump.py --snapshot ./snap

    # stand up a tiny engine, replay a scenario, dump everything
    PYTHONPATH=src python scripts/obs_dump.py --live \
        [--arch qwen2-0.5b] [--scenario steady] [--mega]

Every path validates before printing (obs.metrics.validate_exposition
/ obs.trace.validate_trace), so this doubles as the CI artifact
checker.
"""
import argparse
import json
import re
import sys

sys.path.insert(0, "src")

_BLOCKS = " ▁▂▃▄▅▆▇█"


def dump_metrics(path: str) -> None:
    from repro.obs.metrics import validate_exposition
    text = open(path).read()
    if path.endswith(".json"):
        doc = json.loads(text)
        print(f"{path}: JSON metrics, {len(doc)} families")
        for name, fam in sorted(doc.items()):
            print(f"  {fam['type']:<9} {name} "
                  f"({len(fam['samples'])} samples)")
        return
    n = validate_exposition(text)
    print(f"{path}: valid Prometheus exposition, {n} samples")
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            print(f"  {kind:<9} {name}")


def dump_trace(path: str, require_phases: bool = False) -> None:
    from repro.obs.trace import load, validate_trace
    doc = load(path)
    n = validate_trace(doc, require_phases=require_phases)
    print(f"{path}: valid trace, {n} events")
    by = {}
    for ev in doc["traceEvents"]:
        key = (ev["name"].split("/")[0], ev["cat"])
        tot, cnt = by.get(key, (0.0, 0))
        by[key] = (tot + ev.get("dur", 0.0), cnt + 1)
    print(f"  {'phase':<16} {'cat':<8} {'count':>6} {'total ms':>10}")
    for (name, cat), (tot, cnt) in sorted(by.items()):
        print(f"  {name:<16} {cat:<8} {cnt:>6} {tot / 1e3:>10.2f}")


def dump_snapshot(directory: str, step=None) -> None:
    """Decode a serving snapshot's telemetry words and render the
    per-class / per-shard live-occupancy heatmap (t_alloc − t_free:
    pages currently held, by class, by shard) plus the raw telemetry
    table — straight from the committed files, engine-free."""
    import os

    import numpy as np

    from repro.ckpt import checkpoint as CK

    meta_rec, s = CK.read_meta(directory, step)
    extra = meta_rec.get("extra") or {}
    fp = extra.get("fingerprint")
    if fp is None:
        raise SystemExit(f"{directory}: step {s} has no serving "
                         f"fingerprint sidecar (not an engine snapshot)")
    # the fingerprint's describe() rendering carries the ctl word map;
    # parsing it means this tool needs no layout reconstruction
    fields = [(m.group(3), int(m.group(1)), int(m.group(2)))
              for m in re.finditer(r"ctl\[(\d+):(\d+)\]\s+(\S+)",
                                   fp["arena_layout"])]
    info = meta_rec["leaves"]["arena_ctl"]
    ctl = np.load(os.path.join(directory, f"step_{s:08d}",
                               info["file"]))
    ctl = np.atleast_2d(ctl)  # (S, ctl_words)
    print(f"{directory}: snapshot step {s}, arch {fp.get('arch')}, "
          f"variant {fp.get('variant')}, "
          f"{fp.get('num_shards')} shard(s)")
    tele = {name: ctl[:, a:b] for name, a, b in fields
            if name.startswith("t_")}
    if not tele:
        raise SystemExit("snapshot predates the telemetry region "
                         "(no t_* ctl words in its fingerprint)")
    held = tele["t_alloc"] - tele["t_free"]   # (S, C)
    peak = max(1, int(held.max()))
    print(f"\nlive pages held (t_alloc − t_free), peak {peak}:")
    print("        " + " ".join(f"c{c}" for c in range(held.shape[1])))
    for sh in range(held.shape[0]):
        cells = "  ".join(_BLOCKS[min(len(_BLOCKS) - 1,
                                      (int(v) * (len(_BLOCKS) - 1)
                                       + peak - 1) // peak)]
                          for v in held[sh])
        print(f"  shard{sh} {cells}   {held[sh].tolist()}")
    print("\ntelemetry words:")
    for name, a, b in fields:
        if name.startswith("t_"):
            print(f"  {name:<12} {ctl[:, a:b].squeeze().tolist()}")


def live(arch: str, scenario: str, mega: bool) -> None:
    from repro.obs.metrics import validate_exposition
    from repro.obs.trace import Tracer, validate_trace
    from repro.serve.replay import (SCENARIOS, engine_factory,
                                    generate_trace, replay)

    cfg, make = engine_factory(arch)
    eng = make(mega=mega, tracer=Tracer())
    trace = generate_trace(SCENARIOS[scenario], seed=0,
                           vocab_size=cfg.vocab_size)
    result = replay(eng, trace, scenario=scenario)
    print(f"replay summary ({arch}/{scenario}/"
          f"{'mega' if mega else 'host'}):")
    print(json.dumps(result.summary(), indent=2, sort_keys=True))
    print("\nin-kernel telemetry (drained ctl words):")
    for k, v in eng.drain_telemetry().items():
        print(f"  {k:<12} {v.tolist()}")
    text = eng.publish_metrics().to_prometheus()
    n = validate_exposition(text)
    print(f"\nmetrics exposition: valid, {n} samples")
    doc = eng.tracer.to_json()
    validate_trace(doc, require_phases=True)
    print(f"trace: valid, {len(doc['traceEvents'])} events "
          f"(compile and steady ticks both present)")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="validate + pretty-print obs/ artifacts")
    ap.add_argument("--metrics", metavar="PATH",
                    help="Prometheus text (or .json) metrics file")
    ap.add_argument("--trace", metavar="PATH",
                    help="Chrome trace_event JSON file")
    ap.add_argument("--require-phases", action="store_true",
                    help="trace must separate compile from steady "
                         "ticks (the replay acceptance check)")
    ap.add_argument("--snapshot", metavar="DIR",
                    help="serving snapshot directory: decode the ctl "
                         "telemetry words and render the per-class/"
                         "per-shard occupancy heatmap (engine-free)")
    ap.add_argument("--step", type=int, default=None,
                    help="snapshot step (default: newest committed)")
    ap.add_argument("--live", action="store_true",
                    help="replay a scenario on a smoke engine and "
                         "dump metrics + telemetry + trace from it")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scenario", default="steady")
    ap.add_argument("--mega", action="store_true")
    args = ap.parse_args(argv)
    if not (args.metrics or args.trace or args.snapshot or args.live):
        ap.error("nothing to do: pass --metrics, --trace, "
                 "--snapshot, or --live")
    if args.metrics:
        dump_metrics(args.metrics)
    if args.trace:
        dump_trace(args.trace, require_phases=args.require_phases)
    if args.snapshot:
        dump_snapshot(args.snapshot, step=args.step)
    if args.live:
        live(args.arch, args.scenario, args.mega)
    return 0


if __name__ == "__main__":
    sys.exit(main())
