"""Render EXPERIMENTS.md §Dry-run / §Roofline / §Perf-variants tables
from experiments/dryrun/*.json.  The narrative sections are maintained
by hand in EXPERIMENTS.md; this prints markdown to paste/update.

    PYTHONPATH=src python scripts/render_experiments.py [--section all]
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import analyse  # noqa: E402


def load(tagged=False):
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        if bool(r.get("tag")) != tagged:
            continue
        rows.append(r)
    return rows


def dryrun_table():
    print("### Cell × mesh status (baseline configs)\n")
    print("| arch | shape | 16×16 | peak GiB | compile s | "
          "2×16×16 | peak GiB |")
    print("|" + "---|" * 7)
    recs = {}
    for r in load():
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    seen = sorted({(r["arch"], r["shape"]) for r in load()})
    for arch, shape in seen:
        a = recs.get((arch, shape, "pod16x16"))
        b = recs.get((arch, shape, "pod2x16x16"))
        fmt = lambda r: ("✓" if r and r.get("ok") else "✗",
                         f"{r['memory']['peak_bytes']/2**30:.1f}"
                         if r and r.get("ok") else "—",
                         f"{r.get('compile_s', 0)}" if r and r.get("ok")
                         else "—")
        sa, pa, ca = fmt(a)
        sb, pb, _ = fmt(b)
        print(f"| {arch} | {shape} | {sa} | {pa} | {ca} | {sb} | {pb} |")


def roofline_table():
    print("| arch | shape | compute s | mem(hlo) s | mem(hbm) s | "
          "coll s | dominant | useful | roofline | peak GiB |")
    print("|" + "---|" * 10)
    for r in load():
        if r["mesh"] != "pod16x16" or not r.get("ok"):
            continue
        a = analyse(r)
        print(f"| {a['arch']} | {a['shape']} "
              f"| {a['t_compute_s']:.4f} | {a['t_memory_hlo_s']:.3f} "
              f"| {a['t_memory_s']:.4f} | {a['t_collective_s']:.4f} "
              f"| {a['dominant']} | {a['useful_ratio']:.2f} "
              f"| {a['roofline_fraction']:.2f} | {a['peak_gib']:.1f} |")


def perf_table():
    print("| arch | shape | tag | compute s | mem(hbm) s | coll s | "
          "roofline | peak GiB |")
    print("|" + "---|" * 8)
    base = {}
    for r in load():
        if r["mesh"] == "pod16x16" and r.get("ok"):
            base[(r["arch"], r["shape"])] = r
    rows = []
    for r in load(tagged=True):
        if r["mesh"] != "pod16x16":
            continue
        rows.append(r)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["tag"])):
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['tag']} | "
                  f"ERROR {str(r.get('error'))[:40]} | | | | |")
            continue
        a = analyse(r)
        print(f"| {a['arch']} | {a['shape']} | {a['tag']} "
              f"| {a['t_compute_s']:.4f} | {a['t_memory_s']:.4f} "
              f"| {a['t_collective_s']:.4f} "
              f"| {a['roofline_fraction']:.2f} | {a['peak_gib']:.1f} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=("all", "dryrun", "roofline", "perf"))
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("\n## §Dry-run\n")
        dryrun_table()
    if args.section in ("all", "roofline"):
        print("\n## §Roofline (single-pod baselines)\n")
        roofline_table()
    if args.section in ("all", "perf"):
        print("\n## §Perf variants (tagged runs)\n")
        perf_table()
